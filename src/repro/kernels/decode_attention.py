"""Pallas TPU kernel: one-token GQA decode attention over a ragged KV cache.

This is the synchronized-phase local compute of the paper — the per-step
worker time T_local ∝ L_g is dominated by exactly this kernel streaming the
resident KV cache.  TPU-native design:

  * grid = (batch, kv_head, kv_blocks); the kv_blocks axis is the
    *innermost sequential* grid dim, so VMEM scratch (running max / sum /
    accumulator) carries the online softmax across KV blocks
    (flash-decode);
  * KV streamed HBM->VMEM in (BLK_L, hd) tiles, 128-aligned for the MXU;
  * per-request ragged lengths arrive via scalar prefetch (SMEM) and mask
    the tail block with broadcasted iota (8x128 VREG-friendly);
  * GQA: the Gq query heads of one kv head are processed together as the
    matmul's M dim — q tile (Gq, hd) x k tile (hd, BLK_L) on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

_NEG = -1e30


def _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, blk_l: int, n_blocks: int):
    b = pl.program_id(0)
    blk = pl.program_id(2)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (Gq, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (BLK_L, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (BLK_L, hd)
    hd = q.shape[-1]
    scale = jax.lax.rsqrt(jnp.asarray(hd, jnp.float32))

    s = jnp.dot(q * scale, k.T,
                preferred_element_type=jnp.float32)  # (Gq, BLK_L)
    length = lengths_ref[b]
    pos = blk * blk_l + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, _NEG)

    m_prev = m_ref[...]                            # (Gq,)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                # (Gq, BLK_L)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(blk == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("blk_l", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, lengths, *,
                            blk_l: int = 512, interpret: bool = True):
    """q: (B, Hq, hd); k_cache/v_cache: (B, L, Hkv, hd); lengths: (B,).

    Returns (B, Hq, hd).  ``interpret=True`` executes the kernel body in
    Python on CPU (validation mode); on TPU pass interpret=False.
    """
    B, Hq, hd = q.shape
    L, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    blk_l = min(blk_l, L)
    n_blocks = (L + blk_l - 1) // blk_l
    if L % blk_l != 0:
        pad = n_blocks * blk_l - L
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = q.reshape(B, Hkv, G, hd)

    grid = (B, Hkv, n_blocks)
    out = pl.pallas_call(
        functools.partial(_kernel, blk_l=blk_l, n_blocks=n_blocks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, l, L_: (b, h, 0, 0)),
                pl.BlockSpec((1, blk_l, 1, hd),
                             lambda b, h, l, L_: (b, l, h, 0)),
                pl.BlockSpec((1, blk_l, 1, hd),
                             lambda b, h, l, L_: (b, l, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, h, l, L_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, Hq, hd)
