"""Training substrate: AdamW optimizer, train loop, checkpointing."""
from .checkpoint import load_checkpoint, save_checkpoint  # noqa: F401
from .optimizer import (  # noqa: F401
    AdamWConfig,
    OptState,
    adamw_update,
    init_opt_state,
)
from .train_loop import make_train_step, train  # noqa: F401
