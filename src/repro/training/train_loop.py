"""Training loop: jit'd train_step + host loop with logging/checkpointing.

``make_train_step`` builds the canonical step used both by examples (small
models, CPU) and by the dry-run launcher (production meshes, AOT lowering).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import loss_fn
from .checkpoint import save_checkpoint
from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state

PyTree = Any

__all__ = ["TrainState", "make_train_step", "train"]


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: OptState


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *, mesh=None,
                    batch_axes=("data",), act_spec=None,
                    compute_dtype="bfloat16", grad_accum: int = 1,
                    grad_shardings=None,
                    remat: bool = True) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (loss, params, opt).

    Mixed precision: fp32 master params (ZeRO-sharded by the launcher) are
    cast to ``compute_dtype`` inside the loss, so FSDP all-gathers and all
    matmuls run in bf16; grads flow back into fp32 Adam state.

    ``grad_accum`` > 1 splits the global batch into microbatches inside a
    ``lax.scan``, dividing peak activation memory by the accumulation
    factor (the grads tree is ZeRO-sharded, so accumulating it is cheap) —
    this is the knob that fits 72B-class train steps on 16 GB chips."""
    cdt = jnp.dtype(compute_dtype)

    def cast(p):
        return p.astype(cdt) if (p.dtype == jnp.float32 and p.ndim > 1) \
            else p

    def lf(p, mb):
        pc = jax.tree.map(cast, p)
        return loss_fn(cfg, pc, mb, mesh=mesh, batch_axes=batch_axes,
                       act_spec=act_spec, remat=remat)

    def train_step(params, opt_state, batch):
        if grad_accum <= 1:
            loss, grads = jax.value_and_grad(lf)(params, batch)
        else:
            B = jax.tree.leaves(batch)[0].shape[0]
            assert B % grad_accum == 0, (B, grad_accum)
            mbsz = B // grad_accum

            def body(carry, i):
                lsum, gsum = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * mbsz, mbsz, axis=0), batch)
                l, g = jax.value_and_grad(lf)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                if grad_shardings is not None:
                    # ZeRO: keep the accumulator sharded like the params so
                    # each microbatch's grad is reduce-scattered, not
                    # all-reduced (perf iteration: qwen2-72b train)
                    gsum = jax.lax.with_sharding_constraint(
                        gsum, grad_shardings)
                return (lsum + l, gsum), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (lsum, gsum), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0),
                jnp.arange(grad_accum))
            loss = lsum / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        new_params, new_opt = adamw_update(opt_cfg, params, grads, opt_state)
        return loss, new_params, new_opt

    return train_step


def train(
    cfg: ModelConfig,
    *,
    params: PyTree,
    batches,
    opt_cfg: Optional[AdamWConfig] = None,
    mesh=None,
    log_every: int = 10,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    log_fn=print,
) -> tuple[PyTree, list[float]]:
    """Host training loop over an iterable of batches; returns the trained
    params and the loss history."""
    opt_cfg = opt_cfg or AdamWConfig()
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, mesh=mesh))
    losses = []
    t0 = time.time()
    for i, batch in enumerate(batches):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, params, opt_state = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if log_every and i % log_every == 0:
            log_fn(f"step {i:5d} loss {losses[-1]:.4f} "
                   f"({time.time() - t0:.1f}s)")
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, i + 1,
                            {"params": params, "opt_m": opt_state.m,
                             "opt_v": opt_state.v})
    return params, losses
