"""Minimal checkpointing: flat-key .npz snapshots of (params, opt state,
step) with pytree-structure JSON sidecars.  No orbax dependency."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    np.savez(path, **flat)
    meta = {"step": step, "keys": sorted(flat.keys())}
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    return path


def load_checkpoint(ckpt_dir: str, like: PyTree, step: int | None = None
                    ) -> tuple[PyTree, int]:
    """Restore into the structure of ``like`` (shapes must match)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f[5:13]) for f in os.listdir(ckpt_dir)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None
