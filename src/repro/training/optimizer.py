"""Pure-jnp AdamW with gradient clipping and cosine schedule.

State is a pytree mirroring the params (m, v in fp32), so the launcher can
shard it with the same rules as the parameters (ZeRO-style when the rules
put params on ("data", "model"))."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update",
           "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray       # ()
    m: PyTree               # fp32, like params
    v: PyTree               # fp32, like params


def init_opt_state(params: PyTree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: PyTree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                 state: OptState) -> tuple[PyTree, OptState]:
    """One AdamW step (params fp32 master copies)."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step=step, m=new_m, v=new_v)
