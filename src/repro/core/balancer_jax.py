"""BF-IO as a composable, jittable JAX module.

The host-side reference solver lives in ``io_solver``; this module provides
a pure-``jax.lax`` implementation with static shapes so the balance step can
be fused into a device-side serving loop (or dispatched per-step without
host round-trips).  Construction is greedy LPT (a ``fori_loop`` over
candidates in size order); refinement is a fixed number of best-improving
pairwise swap iterations (the exchange argument of the proofs, vectorized
over all candidate pairs with a top-3 exclusion trick).

Shapes (static under jit):
    base  : (G, W) f32   predicted resident-load trajectories, W = H+1
    caps  : (G,)  i32    free slots per worker
    cands : (N, W) f32   candidate contribution trajectories (zero-padded)
    valid : (N,)  bool   which candidate rows are real
Returns
    assign: (N,) i32     worker id per candidate, -1 = not admitted
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["bfio_assign", "windowed_imbalance"]


def windowed_imbalance(loads: jnp.ndarray) -> jnp.ndarray:
    """J = sum_h (G * max_g loads[g,h] - sum_g loads[g,h])."""
    G = loads.shape[0]
    return jnp.sum(G * loads.max(axis=0) - loads.sum(axis=0))


def _greedy(base, caps, cands, valid, n_admit):
    G, W = base.shape
    N = cands.shape[0]
    totals = jnp.where(valid, cands.sum(axis=1), -jnp.inf)
    order = jnp.argsort(-totals)  # largest first, invalid last

    def body(t, carry):
        loads, caps_left, assign, admitted = carry
        i = order[t]
        c = cands[i]
        # top-2 per step-of-window for the exclusion trick
        top1 = loads.max(axis=0)
        arg1 = loads.argmax(axis=0)
        masked = jnp.where(
            jnp.arange(G)[:, None] == arg1[None, :], -jnp.inf, loads)
        top2 = masked.max(axis=0)
        excl = jnp.where(jnp.arange(G)[:, None] == arg1[None, :],
                         top2[None, :], top1[None, :])           # (G, W)
        scores = jnp.maximum(excl, loads + c[None, :]).sum(axis=1)
        scores = jnp.where(caps_left > 0, scores, jnp.inf)
        g = jnp.argmin(scores)
        ok = (valid[i] & (admitted < n_admit)
              & jnp.isfinite(scores[g]))
        loads = loads.at[g].add(jnp.where(ok, c, 0.0))
        caps_left = caps_left.at[g].add(jnp.where(ok, -1, 0))
        assign = assign.at[i].set(jnp.where(ok, g, -1))
        admitted = admitted + jnp.where(ok, 1, 0)
        return loads, caps_left, assign, admitted

    init = (base.astype(jnp.float32), caps.astype(jnp.int32),
            jnp.full((N,), -1, dtype=jnp.int32), jnp.int32(0))
    loads, caps_left, assign, _ = jax.lax.fori_loop(0, N, body, init)
    return loads, caps_left, assign


def _swap_once(loads, cands, assign, valid):
    """One best-improving pairwise swap over all admitted candidate pairs."""
    G, W = loads.shape
    N = cands.shape[0]
    admitted = (assign >= 0) & valid
    # top-3 per window position, for max-excluding-two-rows
    idx = jnp.argsort(-loads, axis=0)            # (G, W)
    t1, t2, t3 = idx[0], idx[1], idx[jnp.minimum(2, G - 1)]
    v1 = jnp.take_along_axis(loads, t1[None, :], axis=0)[0]
    v2 = jnp.take_along_axis(loads, t2[None, :], axis=0)[0]
    v3 = jnp.take_along_axis(loads, t3[None, :], axis=0)[0]

    gi = assign                                   # (N,)
    lo_i = jnp.where(admitted[:, None], loads[jnp.clip(gi, 0)], 0.0)  # (N, W)

    def excl2(ga, gb):
        # max over workers excluding rows ga, gb; ga/gb: (..., ) ints
        # pick from top-3 per window position
        e1 = (t1[None, None, :] != ga[..., None]) & \
             (t1[None, None, :] != gb[..., None])
        e2 = (t2[None, None, :] != ga[..., None]) & \
             (t2[None, None, :] != gb[..., None])
        out = jnp.where(e1, v1[None, None, :],
                        jnp.where(e2, v2[None, None, :], v3[None, None, :]))
        return out

    ga = jnp.broadcast_to(gi[:, None], (N, N))
    gb = jnp.broadcast_to(gi[None, :], (N, N))
    diff = cands[None, :, :] - cands[:, None, :]   # c_j - c_i, (N, N, W)
    la_new = lo_i[:, None, :] + diff               # row of g_i after swap
    lb_new = lo_i[None, :, :] - diff               # row of g_j after swap
    mx = jnp.maximum(excl2(ga, gb), jnp.maximum(la_new, lb_new))
    # windowed sum of maxima after the swap (sum term is invariant)
    val = mx.sum(axis=2)                           # (N, N)
    feasible = (admitted[:, None] & admitted[None, :]
                & (ga != gb))
    cur = loads.max(axis=0).sum()
    val = jnp.where(feasible, val, jnp.inf)
    flat = jnp.argmin(val)
    bi, bj = jnp.unravel_index(flat, val.shape)
    improve = val[bi, bj] < cur - 1e-6

    def apply(args):
        loads, assign = args
        ci, cj = cands[bi], cands[bj]
        gi_, gj_ = assign[bi], assign[bj]
        loads = loads.at[gi_].add(cj - ci)
        loads = loads.at[gj_].add(ci - cj)
        assign = assign.at[bi].set(gj_)
        assign = assign.at[bj].set(gi_)
        return loads, assign

    loads, assign = jax.lax.cond(improve, apply, lambda a: a, (loads, assign))
    return loads, assign, improve


@functools.partial(jax.jit, static_argnames=("swap_iters",))
def bfio_assign(base, caps, cands, valid, n_admit, swap_iters: int = 8):
    """Jitted BF-IO assignment (greedy + fixed-budget swap refinement)."""
    base = jnp.asarray(base, dtype=jnp.float32)
    cands = jnp.asarray(cands, dtype=jnp.float32)
    loads, caps_left, assign = _greedy(base, caps, cands, valid, n_admit)

    def body(_, carry):
        loads, assign = carry
        loads, assign, _ = _swap_once(loads, cands, assign, valid)
        return loads, assign

    loads, assign = jax.lax.fori_loop(0, swap_iters, body, (loads, assign))
    return assign
