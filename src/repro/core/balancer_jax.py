"""BF-IO as a composable, jittable JAX module.

The host-side reference solver lives in ``io_solver``; this module provides
a pure-``jax.lax`` implementation with static shapes so the balance step can
be fused into a device-side serving loop (or dispatched per-step without
host round-trips).  Construction is greedy LPT (a ``fori_loop`` over
candidates in size order); refinement is a fixed number of best-improving
pairwise swap iterations (the exchange argument of the proofs).

Refinement backends
-------------------
The swap search dominates solve cost.  Three interchangeable backends
compute the identical best-improving pair per iteration (see
``repro.kernels.bfio_swap`` for the math):

* ``method="dense"`` — the original formulation: materialize the full
  (N, N, W) post-swap tensor and take a flat argmin.  O(N^2 W) memory
  per iteration; kept as the measured pre-optimization baseline and
  small-instance oracle.
* ``method="xla"`` (default) — the same reduction tiled over candidate
  row blocks (``lax.map``, peak memory O(TILE * N * W)); the production
  CPU path.
* ``method="pallas"`` — a Pallas kernel on a (N/TILE_I, N/TILE_J) grid
  with the running per-row argmin carried in the revisited output block,
  so no pairwise tensor is ever materialized; interpret mode off-TPU.

Candidate pruning
-----------------
``prune_k=K`` restricts the swap search to the top-K admitted candidates
by windowed contribution (sum over the lookahead window).  Exchanging two
admitted candidates never changes *which* candidates are admitted, so the
pruned set is computed once per solve and refinement permutes assignments
within it: pair-search cost drops from N^2 to K^2 per iteration.  Small
candidates move the windowed max least, so quality loss is bounded and
measured (see benchmarks/balancer_bench.py); ``prune_k=None`` keeps the
search exact.

Batched solving
---------------
``bfio_assign_batch`` vmaps the whole solve over a leading cluster axis —
independent (base, caps, cands) instances solved in one compiled call for
fleet-scale sweeps (G up to 1024, thousands of candidates).

Shapes (static under jit):
    base  : (G, W) f32   predicted resident-load trajectories, W = H+1
    caps  : (G,)  i32    free slots per worker
    cands : (N, W) f32   candidate contribution trajectories (zero-padded)
    valid : (N,)  bool   which candidate rows are real
Returns
    assign: (N,) i32     worker id per candidate, -1 = not admitted
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels.bfio_swap import swap_best_pallas, swap_best_xla

__all__ = ["bfio_assign", "bfio_assign_batch", "windowed_imbalance"]


def windowed_imbalance(loads: jnp.ndarray) -> jnp.ndarray:
    """J = sum_h (G * max_g loads[g,h] - sum_g loads[g,h])."""
    G = loads.shape[0]
    return jnp.sum(G * loads.max(axis=0) - loads.sum(axis=0))


def _greedy(base, caps, cands, valid, n_admit):
    G, W = base.shape
    N = cands.shape[0]
    totals = jnp.where(valid, cands.sum(axis=1), -jnp.inf)
    order = jnp.argsort(-totals)  # largest first, invalid last

    def body(t, carry):
        loads, caps_left, assign, admitted = carry
        i = order[t]
        c = cands[i]
        # top-2 per step-of-window for the exclusion trick
        top1 = loads.max(axis=0)
        arg1 = loads.argmax(axis=0)
        masked = jnp.where(
            jnp.arange(G)[:, None] == arg1[None, :], -jnp.inf, loads)
        top2 = masked.max(axis=0)
        excl = jnp.where(jnp.arange(G)[:, None] == arg1[None, :],
                         top2[None, :], top1[None, :])           # (G, W)
        scores = jnp.maximum(excl, loads + c[None, :]).sum(axis=1)
        scores = jnp.where(caps_left > 0, scores, jnp.inf)
        g = jnp.argmin(scores)
        ok = (valid[i] & (admitted < n_admit)
              & jnp.isfinite(scores[g]))
        loads = loads.at[g].add(jnp.where(ok, c, 0.0))
        caps_left = caps_left.at[g].add(jnp.where(ok, -1, 0))
        assign = assign.at[i].set(jnp.where(ok, g, -1))
        admitted = admitted + jnp.where(ok, 1, 0)
        return loads, caps_left, assign, admitted

    init = (base.astype(jnp.float32), caps.astype(jnp.int32),
            jnp.full((N,), -1, dtype=jnp.int32), jnp.int32(0))
    loads, caps_left, assign, _ = jax.lax.fori_loop(0, N, body, init)
    return loads, caps_left, assign


def _swap_once_dense(loads, cands, assign, valid):
    """One best-improving pairwise swap, dense O(N^2 W) formulation.

    The pre-optimization baseline: materializes every pairwise post-swap
    trajectory at once.  Semantically identical to the tiled backends.
    """
    G, W = loads.shape
    N = cands.shape[0]
    admitted = (assign >= 0) & valid
    # top-3 per window position, for max-excluding-two-rows
    idx = jnp.argsort(-loads, axis=0)            # (G, W)
    t1, t2, t3 = idx[0], idx[1], idx[jnp.minimum(2, G - 1)]
    v1 = jnp.take_along_axis(loads, t1[None, :], axis=0)[0]
    v2 = jnp.take_along_axis(loads, t2[None, :], axis=0)[0]
    v3 = jnp.take_along_axis(loads, t3[None, :], axis=0)[0]

    gi = assign                                   # (N,)
    lo_i = jnp.where(admitted[:, None], loads[jnp.clip(gi, 0)], 0.0)  # (N, W)

    def excl2(ga, gb):
        # max over workers excluding rows ga, gb; ga/gb: (..., ) ints
        # pick from top-3 per window position
        e1 = (t1[None, None, :] != ga[..., None]) & \
             (t1[None, None, :] != gb[..., None])
        e2 = (t2[None, None, :] != ga[..., None]) & \
             (t2[None, None, :] != gb[..., None])
        out = jnp.where(e1, v1[None, None, :],
                        jnp.where(e2, v2[None, None, :], v3[None, None, :]))
        return out

    ga = jnp.broadcast_to(gi[:, None], (N, N))
    gb = jnp.broadcast_to(gi[None, :], (N, N))
    diff = cands[None, :, :] - cands[:, None, :]   # c_j - c_i, (N, N, W)
    la_new = lo_i[:, None, :] + diff               # row of g_i after swap
    lb_new = lo_i[None, :, :] - diff               # row of g_j after swap
    mx = jnp.maximum(excl2(ga, gb), jnp.maximum(la_new, lb_new))
    # windowed sum of maxima after the swap (sum term is invariant)
    val = mx.sum(axis=2)                           # (N, N)
    feasible = (admitted[:, None] & admitted[None, :]
                & (ga != gb))
    val = jnp.where(feasible, val, jnp.inf)
    flat = jnp.argmin(val)
    bi, bj = jnp.unravel_index(flat, val.shape)
    return _apply_best(loads, cands, assign, val[bi, bj], bi, bj)


def _apply_best(loads, cands, assign, best_val, bi, bj):
    """Apply the swap (bi, bj) iff it improves the windowed max-sum."""
    cur = loads.max(axis=0).sum()
    improve = best_val < cur - 1e-6

    def apply(args):
        loads, assign = args
        ci, cj = cands[bi], cands[bj]
        gi_, gj_ = assign[bi], assign[bj]
        loads = loads.at[gi_].add(cj - ci)
        loads = loads.at[gj_].add(ci - cj)
        assign = assign.at[bi].set(gj_)
        assign = assign.at[bj].set(gi_)
        return loads, assign

    loads, assign = jax.lax.cond(improve, apply, lambda a: a, (loads, assign))
    return loads, assign, improve


def _swap_once_tiled(loads, cands, assign, valid, *, method, tile, interpret):
    """One best-improving swap via the tiled (blockwise-argmin) backends."""
    if method == "pallas":
        vals, args = swap_best_pallas(loads, cands, assign, valid,
                                      tile_i=tile, tile_j=tile,
                                      interpret=interpret)
    else:
        vals, args = swap_best_xla(loads, cands, assign, valid, tile_i=tile)
    bi = jnp.argmin(vals)
    bj = args[bi]
    return _apply_best(loads, cands, assign, vals[bi], bi, bj)


def _refine(loads, assign, cands, valid, *, swap_iters, method, tile,
            prune_k, interpret):
    """Fixed-budget swap refinement, optionally in a pruned top-K subspace.

    Swaps exchange two *admitted* candidates, so the admitted set is
    invariant under refinement and the top-K pool can be picked once.
    """
    N = cands.shape[0]
    if method == "dense":
        def body(_, carry):
            loads, assign = carry
            loads, assign, _ = _swap_once_dense(loads, cands, assign, valid)
            return loads, assign
        return jax.lax.fori_loop(0, swap_iters, body, (loads, assign))

    if prune_k is not None and prune_k <= 0:
        return loads, assign                # empty swap pool: nothing to do
    if prune_k is not None and prune_k < N:
        admitted = (assign >= 0) & valid
        totals = jnp.where(admitted, cands.sum(axis=1), -jnp.inf)
        _, pool = jax.lax.top_k(totals, prune_k)            # (K,)
        sub_cands = cands[pool]
        sub_valid = valid[pool]
        sub_assign = assign[pool]

        def body(_, carry):
            loads, sub_assign = carry
            loads, sub_assign, _ = _swap_once_tiled(
                loads, sub_cands, sub_assign, sub_valid,
                method=method, tile=tile, interpret=interpret)
            return loads, sub_assign

        loads, sub_assign = jax.lax.fori_loop(0, swap_iters, body,
                                              (loads, sub_assign))
        return loads, assign.at[pool].set(sub_assign)

    def body(_, carry):
        loads, assign = carry
        loads, assign, _ = _swap_once_tiled(
            loads, cands, assign, valid,
            method=method, tile=tile, interpret=interpret)
        return loads, assign

    return jax.lax.fori_loop(0, swap_iters, body, (loads, assign))


@functools.partial(jax.jit, static_argnames=("swap_iters", "method", "tile",
                                             "prune_k", "interpret"))
def bfio_assign(base, caps, cands, valid, n_admit, swap_iters: int = 8,
                *, method: str = "xla", tile: int = 128,
                prune_k: int | None = None, interpret: bool = True):
    """Jitted BF-IO assignment (greedy + fixed-budget swap refinement).

    ``method`` selects the swap-search backend ("xla" | "pallas" |
    "dense"), ``tile`` the block size, ``prune_k`` the optional top-K
    candidate pruning, ``interpret`` the Pallas interpret mode (keep True
    off-TPU).  All backends return identical assignments for the same
    inputs; ``prune_k`` trades a measured sliver of objective for a K^2/N^2
    reduction in pair-search cost.
    """
    base = jnp.asarray(base, dtype=jnp.float32)
    cands = jnp.asarray(cands, dtype=jnp.float32)
    loads, caps_left, assign = _greedy(base, caps, cands, valid, n_admit)
    loads, assign = _refine(loads, assign, cands, valid,
                            swap_iters=swap_iters, method=method, tile=tile,
                            prune_k=prune_k, interpret=interpret)
    return assign


@functools.partial(jax.jit, static_argnames=("swap_iters", "method", "tile",
                                             "prune_k"))
def bfio_assign_batch(base, caps, cands, valid, n_admit, swap_iters: int = 8,
                      *, method: str = "xla", tile: int = 128,
                      prune_k: int | None = None):
    """Batched BF-IO: solve C independent cluster instances in one call.

    Shapes carry a leading cluster axis: base (C, G, W), caps (C, G),
    cands (C, N, W), valid (C, N), n_admit (C,).  Returns (C, N) i32.
    Uses the XLA tiled backend (vmap-compatible); intended for fleet
    sweeps where many clusters are balanced per barrier step.
    """
    if method == "pallas":  # pallas_call batching is not wired up
        method = "xla"
    solve = functools.partial(bfio_assign, swap_iters=swap_iters,
                              method=method, tile=tile, prune_k=prune_k)
    return jax.vmap(solve)(base, caps, cands, valid, n_admit)
