"""Routing policies: FCFS (Algorithm 2), JSQ, RR, Power-of-d, and BF-IO.

All policies implement ``assign(ctx) -> np.ndarray`` mapping each waiting
candidate index to a worker id (or -1 to keep waiting).  The baselines are
*size-agnostic* (they may observe queue/batch counts but not workloads),
exactly as described in Appendix A.1/B; BF-IO observes current loads,
candidate prefill sizes (known at prefill→decode handoff — the KV cache has
a definite size), and short-lookahead survival predictions for active jobs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import io_solver
from .lookahead import Predictor, trajectories
from .workload import DriftModel

__all__ = [
    "SchedulerContext",
    "Policy",
    "FCFSPolicy",
    "JSQPolicy",
    "RoundRobinPolicy",
    "PowerOfDPolicy",
    "BFIOPolicy",
    "make_policy",
]


@dataclasses.dataclass
class SchedulerContext:
    """Observable state handed to a policy at step k.

    When the serving engine runs chunked prefill (vLLM-style interleaving,
    :mod:`repro.serving.scheduler`), admitted requests spend a few steps
    mid-prefill before they start decoding.  Those jobs appear in the
    ``active_*`` arrays with ``active_age == 0`` and their *current*
    workload ``active_w`` equal to the prompt tokens prefilled so far;
    ``active_prefill_remaining`` exposes the outstanding prompt tokens so
    slice-aware policies can anticipate the load each worker is still
    committed to absorb.  Engines without chunking pass zeros (and the
    simulator omits the field entirely), so policies must treat ``None``
    as "no prefill in flight".
    """

    k: int
    loads: np.ndarray            # (G,) pre-admission workloads
    counts: np.ndarray           # (G,) number of active requests
    caps: np.ndarray             # (G,) free slots
    wait_prefill: np.ndarray     # (n,) candidate prefill sizes s_i (arrival order)
    # Active-job details (for lookahead policies):
    active_worker: np.ndarray    # (m,) worker of each active job
    active_w: np.ndarray         # (m,) current per-step workload of each job
    active_age: np.ndarray       # (m,) decode steps already done
    active_remaining: np.ndarray  # (m,) TRUE remaining steps (oracle use only)
    drift: DriftModel
    rng: np.random.Generator
    # (m,) prompt tokens of each active job not yet prefilled (0 = job is
    # decoding).  None when the runtime has no chunked prefill.
    active_prefill_remaining: Optional[np.ndarray] = None

    @property
    def G(self) -> int:
        return int(self.loads.shape[0])

    @property
    def n_wait(self) -> int:
        return int(self.wait_prefill.shape[0])

    @property
    def n_slots(self) -> int:
        return int(self.caps.sum())

    @property
    def n_admit(self) -> int:
        """U(k) = min(|R_wait|, sum_g cap[g]) — full-utilization constraint."""
        return min(self.n_wait, self.n_slots)


class Policy:
    name = "base"

    def reset(self) -> None:  # pragma: no cover - stateless default
        pass

    def assign(self, ctx: SchedulerContext) -> np.ndarray:
        raise NotImplementedError


class FCFSPolicy(Policy):
    """Appendix B, Algorithm 2: pop the oldest waiting request, place it on
    the worker with the most free slots (ties: lowest index)."""

    name = "fcfs"

    def assign(self, ctx: SchedulerContext) -> np.ndarray:
        out = np.full(ctx.n_wait, -1, dtype=np.int64)
        caps = ctx.caps.copy()
        for i in range(ctx.n_admit):
            g = int(np.argmax(caps))
            if caps[g] <= 0:
                break
            out[i] = g
            caps[g] -= 1
        return out


class JSQPolicy(Policy):
    """Join-Shortest-Queue on request *counts* (the vLLM/SGLang-style proxy:
    queue length, not workload — Appendix A.1.1)."""

    name = "jsq"

    def assign(self, ctx: SchedulerContext) -> np.ndarray:
        out = np.full(ctx.n_wait, -1, dtype=np.int64)
        caps = ctx.caps.copy()
        counts = ctx.counts.astype(np.int64).copy()
        for i in range(ctx.n_admit):
            masked = np.where(caps > 0, counts, np.iinfo(np.int64).max)
            g = int(np.argmin(masked))
            if caps[g] <= 0:
                break
            out[i] = g
            caps[g] -= 1
            counts[g] += 1
        return out


class RoundRobinPolicy(Policy):
    """Cyclic dispatch irrespective of size/load (Appendix A.1.1)."""

    name = "rr"

    def __init__(self) -> None:
        self._next = 0

    def reset(self) -> None:
        self._next = 0

    def assign(self, ctx: SchedulerContext) -> np.ndarray:
        out = np.full(ctx.n_wait, -1, dtype=np.int64)
        caps = ctx.caps.copy()
        G = ctx.G
        for i in range(ctx.n_admit):
            placed = False
            for _ in range(G):
                g = self._next % G
                self._next += 1
                if caps[g] > 0:
                    out[i] = g
                    caps[g] -= 1
                    placed = True
                    break
            if not placed:
                break
        return out


class PowerOfDPolicy(Policy):
    """Sample d workers, route to the least-count one among them."""

    name = "pod"

    def __init__(self, d: int = 2) -> None:
        self.d = int(d)
        self.name = f"pod{d}"

    def assign(self, ctx: SchedulerContext) -> np.ndarray:
        out = np.full(ctx.n_wait, -1, dtype=np.int64)
        caps = ctx.caps.copy()
        counts = ctx.counts.astype(np.int64).copy()
        G = ctx.G
        for i in range(ctx.n_admit):
            avail = np.nonzero(caps > 0)[0]
            if len(avail) == 0:
                break
            d = min(self.d, len(avail))
            sample = ctx.rng.choice(avail, size=d, replace=False)
            g = int(sample[np.argmin(counts[sample])])
            out[i] = g
            caps[g] -= 1
            counts[g] += 1
        return out


class BFIOPolicy(Policy):
    """Balance-Future with Integer Optimization (Algorithm 1).

    Parameters
    ----------
    H:
        lookahead window length (H=0 is the prediction-free myopic case
        analyzed in Theorems 1–3).
    predictor:
        survival predictor for *active* jobs (OraclePredictor /
        GeometricPredictor / NoisyOraclePredictor).
    p_new:
        geometric prior parameter for *new* candidates' survival within the
        window (their decode lengths are unknown at admission). ``None``
        treats candidates as surviving the whole window (conservative).
    candidate_window:
        the router considers the first ``candidate_window * U`` waiting
        requests (arrival order) as the selectable pool — bounded staleness,
        bounded solve cost.
    """

    def __init__(
        self,
        H: int = 0,
        predictor: Optional[Predictor] = None,
        p_new: Optional[float] = None,
        candidate_window: int = 4,
        min_pool: int = 128,
        refine: bool = True,
    ) -> None:
        self.H = int(H)
        # Default lookahead signal: clairvoyant *within the window* (the
        # paper's short-horizon finish signals).  NB: a non-discriminative
        # predictor (e.g. GeometricPredictor: identical survival for all
        # jobs) makes H>0 behave like H=0 — lookahead only helps when it
        # can tell imminent finishers apart.
        from .lookahead import OraclePredictor
        self.predictor = predictor or OraclePredictor()
        self.p_new = p_new
        self.candidate_window = int(candidate_window)
        self.min_pool = int(min_pool)
        self.refine = refine
        self.name = f"bfio_h{H}"

    def _candidate_traj(self, ctx: SchedulerContext, pool: np.ndarray) -> np.ndarray:
        H = self.H
        s = ctx.wait_prefill[pool]
        n = len(pool)
        growth = np.zeros(H + 1)
        for h in range(1, H + 1):
            growth[h] = growth[h - 1] + ctx.drift.increment(ctx.k + h)
        traj = s[:, None] + growth[None, :]
        if self.p_new is not None and H > 0:
            surv = (1.0 - self.p_new) ** np.arange(H + 1, dtype=np.float64)
            traj = traj * surv[None, :]
        return traj.astype(np.float64)

    def _base_traj(self, ctx: SchedulerContext) -> np.ndarray:
        """Predicted per-worker trajectories of resident jobs over the window."""
        H = self.H
        G = ctx.G
        base = np.zeros((G, H + 1), dtype=np.float64)
        m = len(ctx.active_w)
        if m == 0:
            return base
        if H == 0:
            np.add.at(base[:, 0], ctx.active_worker, ctx.active_w)
            return base
        traj = trajectories(
            ctx.active_w, ctx.active_remaining, ctx.active_age,
            drift=ctx.drift, k=ctx.k, H=H, predictor=self.predictor,
            rng=ctx.rng,
        )  # (m, H+1)
        np.add.at(base, ctx.active_worker, traj)
        return base

    def assign(self, ctx: SchedulerContext) -> np.ndarray:
        out = np.full(ctx.n_wait, -1, dtype=np.int64)
        U = ctx.n_admit
        if U == 0:
            return out
        pool_size = min(ctx.n_wait,
                        max(U, self.candidate_window * U, self.min_pool))
        pool = np.arange(pool_size)
        base = self._base_traj(ctx)
        cands = self._candidate_traj(ctx, pool)
        a = io_solver.solve_io(base, ctx.caps, cands, n_admit=U,
                               refine=self.refine,
                               max_iters=min(64, 4 * U + 8))
        out[pool] = a
        return out


def make_policy(name: str, **kw) -> Policy:
    name = name.lower()
    if name == "fcfs":
        return FCFSPolicy()
    if name == "jsq":
        return JSQPolicy()
    if name in ("rr", "round_robin"):
        return RoundRobinPolicy()
    if name.startswith("pod"):
        d = int(name[3:]) if len(name) > 3 else kw.pop("d", 2)
        return PowerOfDPolicy(d=d)
    if name.startswith("bfio"):
        if "_h" in name:
            kw.setdefault("H", int(name.split("_h")[1]))
        return BFIOPolicy(**kw)
    raise ValueError(f"unknown policy {name!r}")
