"""Core library: the paper's load-balancing principle (BF-IO) and its
supporting machinery — workload models, policies, the (IO) solver, the
jittable JAX balancer, power/energy theory, and the serving simulator."""
from .workload import (  # noqa: F401
    ArrivalInstance,
    DriftModel,
    Request,
    constant_drift,
    drift_for_family,
    fractional_drift,
    make_instance,
    scaled_drift,
    unit_drift,
)
from .io_solver import (  # noqa: F401
    local_search,
    objective,
    solve_exact,
    solve_greedy,
    solve_io,
)
from .lookahead import (  # noqa: F401
    GeometricPredictor,
    NoisyOraclePredictor,
    OraclePredictor,
    trajectories,
)
from .policies import (  # noqa: F401
    BFIOPolicy,
    FCFSPolicy,
    JSQPolicy,
    Policy,
    PowerOfDPolicy,
    RoundRobinPolicy,
    SchedulerContext,
    make_policy,
)
from .metrics import SimMetrics, step_imbalance  # noqa: F401
from .energy import (  # noqa: F401
    A100_POWER,
    TPU_V5E_POWER,
    PowerModel,
    asymptotic_saving,
    energy_decomposition,
    energy_sandwich,
    saving_bound,
)
from .simulator import SimConfig, SimTrace, simulate  # noqa: F401
from . import theory  # noqa: F401
