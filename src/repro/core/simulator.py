"""Discrete-event simulator of GPU-based LLM decode serving (Section 6.2).

Models G workers with per-worker concurrency B.  Each simulation step:

  1. reveal arrivals (undiscovered -> wait queue);
  2. the routing policy admits waiting requests into free slots;
  3. loads L_g(k) are computed; the step advances wall-clock by
         dt = C + t_l * max_g L_g(k)                        (Eq. 19)
     and energy integrates the power model over dt (Eqs. 6-9);
  4. every active request produces one token; finished requests leave;
  5. surviving requests' workloads grow by the drift delta_{k+1}.

The simulator is slot-vectorized (numpy struct-of-arrays over (G, B)) so the
paper's G=256, B=72 configuration runs in seconds per policy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .energy import A100_POWER, PowerModel
from .metrics import SimMetrics
from .policies import Policy, SchedulerContext
from .workload import ArrivalInstance

__all__ = ["SimConfig", "SimTrace", "simulate"]

# Paper Section 6.2 time-progression constants (regressed from real traces).
PAPER_C = 9.775e-3        # fixed per-step overhead, seconds
PAPER_T_TOKEN = 1.005e-7  # per-token latency coefficient, seconds/token


@dataclasses.dataclass(frozen=True)
class SimConfig:
    G: int = 256
    B: int = 72
    step_overhead: float = PAPER_C
    t_token: float = PAPER_T_TOKEN
    power: PowerModel = A100_POWER
    max_steps: int = 200_000
    seed: int = 0
    record_loads_every: int = 0   # 0 = don't record per-worker load traces
    time_based_arrivals: bool = False  # reveal by wall-clock arrival_time
    # "central": one waiting pool, the router reshapes batches at every
    # slot release (the paper's main interface).  "instant": requests bind
    # to a per-worker FIFO queue at arrival (Section 7.3's limitation —
    # vLLM-style engines), which strips the router of late information.
    # "instant_ref": the original per-request Python implementation of
    # instant mode, kept verbatim as the step-for-step regression oracle
    # and the pre-optimization baseline for benchmarks/balancer_bench.py.
    dispatch: str = "central"


@dataclasses.dataclass
class SimTrace:
    """Per-step traces for the paper's figures."""

    dt: list = dataclasses.field(default_factory=list)
    t: list = dataclasses.field(default_factory=list)
    imbalance: list = dataclasses.field(default_factory=list)
    max_load: list = dataclasses.field(default_factory=list)
    mean_load: list = dataclasses.field(default_factory=list)
    idle_frac: list = dataclasses.field(default_factory=list)
    avg_power: list = dataclasses.field(default_factory=list)
    n_active: list = dataclasses.field(default_factory=list)
    n_waiting: list = dataclasses.field(default_factory=list)
    loads: list = dataclasses.field(default_factory=list)  # optional (G,) snaps

    def asdict(self) -> dict:
        return {k: np.asarray(v) for k, v in dataclasses.asdict(self).items()}


def simulate(
    instance: ArrivalInstance,
    policy: Policy,
    config: SimConfig = SimConfig(),
    trace: Optional[SimTrace] = None,
) -> SimMetrics:
    """Run ``policy`` on ``instance`` until every request completes."""
    G, B = config.G, config.B
    drift = instance.drift
    rng = np.random.default_rng(config.seed)
    policy.reset()
    instance.reset()

    reqs = instance.requests
    N = len(reqs)
    arr_step = np.array([r.arrival_step for r in reqs], dtype=np.int64)
    arr_time = (np.array([r.arrival_time for r in reqs], dtype=np.float64)
                if config.time_based_arrivals else None)
    prefill = np.array([r.prefill for r in reqs], dtype=np.float64)
    decode_len = np.array([r.decode_len for r in reqs], dtype=np.int64)
    t_start = np.full(N, np.nan)
    t_finish = np.full(N, np.nan)

    # Slot state, flattened (G*B,)
    S = G * B
    slot_req = np.full(S, -1, dtype=np.int64)
    slot_w = np.zeros(S, dtype=np.float64)
    slot_age = np.zeros(S, dtype=np.int64)
    slot_worker = np.repeat(np.arange(G), B)

    waiting: list[int] = []
    instant = config.dispatch in ("instant", "instant_ref")
    instant_ref = config.dispatch == "instant_ref"
    wqueues: list[list[int]] = [[] for _ in range(G)]  # instant mode
    # Instant-mode queue state, maintained incrementally (never recomputed
    # by walking the queues): total queued prefill and queue length per
    # worker.  Matches the recomputed-per-step reference exactly when
    # prefills are float64-exact under addition (integer token counts, as
    # every in-repo workload produces); arbitrary mixed-magnitude floats
    # could differ from "instant_ref" by rounding in the running sum.
    qload = np.zeros(G, dtype=np.float64)
    qlen = np.zeros(G, dtype=np.int64)
    next_reveal = 0          # pointer into arrival-sorted requests
    completed = 0
    t_now = 0.0
    k = 0

    tot_imb = 0.0
    tot_tokens = 0
    tot_energy = 0.0
    tot_time = 0.0
    sum_idle_frac = 0.0
    n_steps_with_load = 0
    sum_power = 0.0

    pm = config.power

    while completed < N and k < config.max_steps:
        # --- 1. reveal arrivals -----------------------------------------
        if config.time_based_arrivals:
            while next_reveal < N and arr_time[next_reveal] <= t_now:
                waiting.append(next_reveal)
                next_reveal += 1
            # if nothing active and nothing waiting, jump to next arrival
            if not waiting and slot_req.max() < 0 and next_reveal < N:
                t_now = float(arr_time[next_reveal])
                continue
        else:
            while next_reveal < N and arr_step[next_reveal] <= k:
                waiting.append(next_reveal)
                next_reveal += 1
            if not waiting and slot_req.max() < 0 and next_reveal < N:
                k = int(arr_step[next_reveal])
                continue

        # --- 2. policy admission ----------------------------------------
        occ = slot_req >= 0
        loads = np.bincount(slot_worker[occ], weights=slot_w[occ], minlength=G)
        counts = np.bincount(slot_worker[occ], minlength=G)
        caps = B - counts
        if instant and instant_ref:
            # Original per-request Python implementation, kept verbatim as
            # the regression oracle for the vectorized path below.
            qload = np.zeros(G)
            qlen = np.zeros(G, dtype=np.int64)
            for g in range(G):
                qlen[g] = len(wqueues[g])
                qload[g] = sum(prefill[r] for r in wqueues[g])
            act_idx = np.nonzero(occ)[0]
            for rid in waiting:
                ctx = SchedulerContext(
                    k=k,
                    loads=loads + qload,
                    counts=(counts + qlen).astype(np.int64),
                    caps=np.maximum(B - counts - qlen, 1).astype(np.int64),
                    wait_prefill=prefill[[rid]],
                    active_worker=slot_worker[act_idx],
                    active_w=slot_w[act_idx],
                    active_age=slot_age[act_idx],
                    active_remaining=(decode_len[slot_req[act_idx]]
                                      - slot_age[act_idx]),
                    drift=drift,
                    rng=rng,
                )
                a = policy.assign(ctx)
                g = (int(a[0]) if len(a) and a[0] >= 0
                     else int(np.argmin(loads + qload)))
                wqueues[g].append(rid)
                qload[g] += prefill[rid]
                qlen[g] += 1
            waiting = []
            free_slots: list[list[int]] = [[] for _ in range(G)]
            for s_idx in np.nonzero(~occ)[0]:
                free_slots[slot_worker[s_idx]].append(int(s_idx))
            for g in range(G):
                while wqueues[g] and free_slots[g]:
                    rid = wqueues[g].pop(0)
                    s_idx = free_slots[g].pop(0)
                    slot_req[s_idx] = rid
                    slot_w[s_idx] = prefill[rid]
                    slot_age[s_idx] = 0
                    t_start[rid] = t_now
                    reqs[rid].assign_step = k
                    reqs[rid].worker = g
            occ = slot_req >= 0
            loads = np.bincount(slot_worker[occ], weights=slot_w[occ],
                                minlength=G)
        elif instant:
            # Vectorized instant mode.  Route every newly arrived request
            # immediately (no pool): the policy sees current loads + queued
            # prefill backlog, one candidate at a time, unconstrained by
            # free slots.  The routing loop itself is inherently sequential
            # (each decision shifts the backlog the next one observes), but
            # the context's active-slot arrays are batched once per step
            # and qload/qlen are carried incrementally across steps.
            if waiting:
                act_idx = np.nonzero(occ)[0]
                active_worker = slot_worker[act_idx]
                active_w = slot_w[act_idx]
                active_age = slot_age[act_idx]
                active_remaining = (decode_len[slot_req[act_idx]]
                                    - slot_age[act_idx])
                for rid in waiting:
                    ctx = SchedulerContext(
                        k=k,
                        loads=loads + qload,
                        counts=(counts + qlen).astype(np.int64),
                        caps=np.maximum(B - counts - qlen, 1).astype(np.int64),
                        wait_prefill=prefill[rid:rid + 1],
                        active_worker=active_worker,
                        active_w=active_w,
                        active_age=active_age,
                        active_remaining=active_remaining,
                        drift=drift,
                        rng=rng,
                    )
                    a = policy.assign(ctx)
                    g = (int(a[0]) if len(a) and a[0] >= 0
                         else int(np.argmin(loads + qload)))
                    wqueues[g].append(rid)
                    qload[g] += prefill[rid]
                    qlen[g] += 1
                waiting = []
            # Vectorized FIFO drain (every step — slot releases must drain
            # the queues even with no new arrivals): free slot indices are
            # ascending, hence grouped by worker; searchsorted over the
            # cumulative free-slot runs yields each worker's slot range
            # without materializing per-worker lists.
            if qlen.any() and not occ.all():
                free = np.nonzero(~occ)[0]
                free_worker = slot_worker[free]
                nfree = np.bincount(free_worker, minlength=G)
                ntake = np.minimum(nfree, qlen)
                gsel = np.nonzero(ntake > 0)[0]
                if len(gsel) > 0:
                    off = np.searchsorted(free_worker, np.arange(G))
                    rid_parts = []
                    slot_parts = []
                    for g in gsel:
                        g = int(g)
                        t_ = int(ntake[g])
                        q = wqueues[g]
                        rid_parts.extend(q[:t_])
                        wqueues[g] = q[t_:]
                        slot_parts.append(free[off[g]:off[g] + t_])
                        qlen[g] -= t_
                    rids = np.asarray(rid_parts, dtype=np.int64)
                    slots = np.concatenate(slot_parts)
                    np.add.at(qload, slot_worker[slots], -prefill[rids])
                    slot_req[slots] = rids
                    slot_w[slots] = prefill[rids]
                    slot_age[slots] = 0
                    t_start[rids] = t_now
                    for rid, s_idx in zip(rid_parts, slots):
                        reqs[rid].assign_step = k
                        reqs[rid].worker = int(slot_worker[s_idx])
                    occ = slot_req >= 0
                    loads = np.bincount(slot_worker[occ], weights=slot_w[occ],
                                        minlength=G)
        elif waiting and caps.sum() > 0:
            act_idx = np.nonzero(occ)[0]
            ctx = SchedulerContext(
                k=k,
                loads=loads,
                counts=counts.astype(np.int64),
                caps=caps.astype(np.int64),
                wait_prefill=prefill[np.asarray(waiting, dtype=np.int64)],
                active_worker=slot_worker[act_idx],
                active_w=slot_w[act_idx],
                active_age=slot_age[act_idx],
                active_remaining=(decode_len[slot_req[act_idx]]
                                  - slot_age[act_idx]),
                drift=drift,
                rng=rng,
            )
            assignment = policy.assign(ctx)
            if len(assignment) != len(waiting):
                raise RuntimeError(
                    f"{policy.name}: assignment length {len(assignment)} != "
                    f"waiting {len(waiting)}")
            # free slots, ascending (hence grouped by worker): worker g's
            # u-th free slot is free[foff[g] + u]
            free = np.nonzero(~occ)[0]
            foff = np.searchsorted(slot_worker[free], np.arange(G))
            admitted_pos = []
            used = np.zeros(G, dtype=np.int64)
            for pos, g in enumerate(assignment):
                if g < 0:
                    continue
                g = int(g)
                if used[g] >= caps[g]:
                    raise RuntimeError(
                        f"{policy.name}: worker {g} over capacity at step {k}")
                rid = waiting[pos]
                s_idx = int(free[foff[g] + used[g]])
                used[g] += 1
                slot_req[s_idx] = rid
                slot_w[s_idx] = prefill[rid]
                slot_age[s_idx] = 0
                t_start[rid] = t_now
                reqs[rid].assign_step = k
                reqs[rid].worker = g
                admitted_pos.append(pos)
            for pos in sorted(admitted_pos, reverse=True):
                waiting.pop(pos)
            occ = slot_req >= 0
            loads = np.bincount(slot_worker[occ], weights=slot_w[occ],
                                minlength=G)

        # --- 3. step timing, imbalance, energy --------------------------
        lmax = float(loads.max()) if occ.any() else 0.0
        imb = G * lmax - float(loads.sum())
        dt = config.step_overhead + config.t_token * lmax
        u = loads / lmax if lmax > 0 else np.zeros(G)
        step_power = pm.power(u).sum()
        tot_energy += dt * step_power
        tot_time += dt
        t_now += dt
        n_act = int(occ.sum())
        tot_tokens += n_act
        tot_imb += imb
        if lmax > 0:
            sum_idle_frac += float((lmax - loads).mean() / lmax)
            n_steps_with_load += 1
        sum_power += step_power / G

        if trace is not None:
            trace.dt.append(dt)
            trace.t.append(t_now)
            trace.imbalance.append(imb)
            trace.max_load.append(lmax)
            trace.mean_load.append(float(loads.mean()))
            trace.idle_frac.append(
                float((lmax - loads).mean() / lmax) if lmax > 0 else 0.0)
            trace.avg_power.append(step_power / G)
            trace.n_active.append(n_act)
            n_queued = (sum(len(q) for q in wqueues) if instant_ref
                        else int(qlen.sum()))
            trace.n_waiting.append(len(waiting) + n_queued)
            if (config.record_loads_every
                    and k % config.record_loads_every == 0):
                trace.loads.append(loads.copy())

        # --- 4. token generation & completions --------------------------
        act = np.nonzero(occ)[0]
        slot_age[act] += 1
        fin = act[slot_age[act] >= decode_len[slot_req[act]]]
        if len(fin) > 0:
            rids = slot_req[fin]
            t_finish[rids] = t_now
            for rid in rids:
                reqs[rid].finish_step = k
            completed += len(fin)
            slot_req[fin] = -1
            slot_w[fin] = 0.0
            slot_age[fin] = 0

        # --- 5. drift growth for survivors ------------------------------
        surv = slot_req >= 0
        if surv.any():
            slot_w[surv] += drift.increment(k + 1)
        k += 1

    if completed < N:
        raise RuntimeError(
            f"simulation hit max_steps={config.max_steps} with "
            f"{N - completed} requests unfinished")

    done = ~np.isnan(t_finish)
    tpot = float(np.mean((t_finish[done] - t_start[done])
                         / decode_len[done])) if done.any() else float("nan")
    for rid in np.nonzero(done)[0]:
        reqs[rid].t_start = float(t_start[rid])
        reqs[rid].t_finish = float(t_finish[rid])

    return SimMetrics(
        policy=policy.name,
        steps=k,
        avg_imbalance=tot_imb / max(k, 1),
        total_imbalance=tot_imb,
        throughput=tot_tokens / max(tot_time, 1e-12),
        tpot=tpot,
        energy_joules=tot_energy,
        makespan=tot_time,
        total_work=instance.total_work(),
        completed=completed,
        mean_idle_frac=sum_idle_frac / max(n_steps_with_load, 1),
        avg_power_watts=sum_power / max(k, 1),
    )
