"""Evaluation metrics of Sections 3 and 6.3."""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["step_imbalance", "SimMetrics"]


def step_imbalance(loads: np.ndarray) -> float:
    """Imbalance(k) = sum_g (L_max - L_g) = G * L_max - sum_g L_g  (Eq. 2)."""
    loads = np.asarray(loads, dtype=np.float64)
    G = loads.shape[0]
    return float(G * loads.max() - loads.sum())


@dataclasses.dataclass
class SimMetrics:
    """Aggregated results of one simulation run (Section 6.3)."""

    policy: str
    steps: int
    avg_imbalance: float          # Eq. (20)
    total_imbalance: float        # ImbTot, Eq. (12)
    throughput: float             # tokens/s, Eq. (21)
    tpot: float                   # s/token, Eq. (22)
    energy_joules: float          # Eq. (6)/(10)
    makespan: float               # total wall-clock
    total_work: float             # W(I), Eq. (11) — policy independent
    completed: int
    mean_idle_frac: float         # Fig. 1-style barrier idle fraction
    avg_power_watts: float

    @property
    def eta_sum(self) -> float:
        """Normalized imbalance level eta_sum (Eq. 13)."""
        return self.total_imbalance / max(self.total_work, 1e-12)

    def row(self) -> dict:
        return {
            "policy": self.policy,
            "steps": self.steps,
            "avg_imbalance": self.avg_imbalance,
            "throughput_tok_s": self.throughput,
            "tpot_s": self.tpot,
            "energy_MJ": self.energy_joules / 1e6,
            "makespan_s": self.makespan,
            "idle_frac": self.mean_idle_frac,
            "avg_power_W": self.avg_power_watts,
            "eta_sum": self.eta_sum,
            "completed": self.completed,
        }

    def __str__(self) -> str:
        return (
            f"{self.policy:>10s}: imb={self.avg_imbalance:.4g} "
            f"thr={self.throughput:.4g} tok/s tpot={self.tpot:.4g} s "
            f"E={self.energy_joules/1e6:.4g} MJ idle={self.mean_idle_frac:.1%}"
        )
