"""Short-lookahead workload information Ŵ_i^H(k) (Section 4).

The paper's key informational insight: BF-IO does NOT need total-length
prediction of *new* jobs; it needs only a short-horizon description of the
near-future evolution of *currently active* jobs — e.g. "will this request
finish within the next h steps?".

Predictors produce, for a set of jobs with known current workload w and age,
a matrix ``traj[(n, H+1)]`` with traj[i, h] = predicted workload contribution
of job i at step k+h (h=0 is the current step; zero after predicted finish).

Under the LLM drift model, an alive job's contribution at k+h is
``w_i + sum(delta over the next h steps)``; prediction reduces to the
finish-time indicator / survival probability.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol

import numpy as np

from .workload import DriftModel

__all__ = [
    "Predictor",
    "OraclePredictor",
    "GeometricPredictor",
    "NoisyOraclePredictor",
    "trajectories",
]


def _growth(drift: DriftModel, k: int, H: int) -> np.ndarray:
    """Cumulative drift over the window: g[h] = sum delta_{k+1..k+h}."""
    g = np.zeros(H + 1, dtype=np.float64)
    for h in range(1, H + 1):
        g[h] = g[h - 1] + drift.increment(k + h)
    return g


class Predictor(Protocol):
    """Predicts survival weights within the lookahead window."""

    def survival(self, remaining: np.ndarray, ages: np.ndarray,
                 H: int, rng: Optional[np.random.Generator]) -> np.ndarray:
        """Return (n, H+1) matrix p[i, h] in [0,1]: predicted probability
        (or indicator) that job i is still running at step k+h.

        ``remaining``: true remaining steps (oracle inputs may use it;
        prediction-free ones must not). ``ages``: steps already processed.
        """
        ...


@dataclasses.dataclass(frozen=True)
class OraclePredictor:
    """Clairvoyant within the window: knows finish times <= H ahead.

    This is the paper's idealized Ŵ: exact short-horizon completion info —
    far weaker than full-length prediction (still unknowable beyond H).
    """

    def survival(self, remaining, ages, H, rng=None):
        remaining = np.asarray(remaining, dtype=np.int64)
        h = np.arange(H + 1)[None, :]
        return (h < remaining[:, None]).astype(np.float64)


@dataclasses.dataclass(frozen=True)
class GeometricPredictor:
    """Prediction-free prior: decode lengths ~ Geo(p) are memoryless, so the
    survival probability at horizon h is (1-p)^h regardless of age.

    This realizes 'even manual rules' from the paper — no learned model.
    """

    p: float

    def survival(self, remaining, ages, H, rng=None):
        n = len(np.asarray(remaining))
        h = np.arange(H + 1, dtype=np.float64)[None, :]
        return np.broadcast_to((1.0 - self.p) ** h, (n, H + 1)).copy()


@dataclasses.dataclass(frozen=True)
class NoisyOraclePredictor:
    """Oracle whose finish-time estimates are corrupted: with probability
    ``flip`` a job's predicted remaining time is resampled geometrically.
    Models realistic lightweight finish-signal classifiers."""

    flip: float
    p: float

    def survival(self, remaining, ages, H, rng=None):
        rng = rng or np.random.default_rng(0)
        remaining = np.asarray(remaining, dtype=np.int64).copy()
        n = len(remaining)
        bad = rng.random(n) < self.flip
        if bad.any():
            remaining = remaining.copy()
            remaining[bad] = rng.geometric(self.p, size=int(bad.sum()))
        h = np.arange(H + 1)[None, :]
        return (h < remaining[:, None]).astype(np.float64)


def trajectories(
    current_w: np.ndarray,
    remaining: np.ndarray,
    ages: np.ndarray,
    *,
    drift: DriftModel,
    k: int,
    H: int,
    predictor: Predictor,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Ŵ_i^H(k) as an (n, H+1) matrix of predicted contributions.

    traj[i, h] = (w_i + growth[h]) * survival[i, h].
    """
    current_w = np.asarray(current_w, dtype=np.float64)
    n = current_w.shape[0]
    if n == 0:
        return np.zeros((0, H + 1), dtype=np.float64)
    surv = predictor.survival(remaining, ages, H, rng)
    growth = _growth(drift, k, H)[None, :]
    return (current_w[:, None] + growth) * surv
