"""Closed-form theoretical quantities from Section 5.

These are the *formulas* of Theorems 1-3, Remark 1 / Eq. (17) and
Corollary 1, used by benchmarks/theory_validation.py to check that measured
imbalance-improvement ratios scale as the theory predicts.
"""
from __future__ import annotations

import math


from .energy import PowerModel, asymptotic_saving, saving_bound

__all__ = [
    "iir_homogeneous",
    "iir_geometric",
    "iir_general_drift",
    "snapshot_sigma",
    "eta_sum_fcfs_lower",
    "energy_saving_guarantee",
    "predicted_fcfs_imbalance",
    "predicted_bfio_imbalance",
]


def snapshot_sigma(sigma_s: float, p: float) -> float:
    """sigma_snap^2 = sigma_s^2 + (1-p)/p^2 (Theorem 2 proof, Eq. C15)."""
    return math.sqrt(sigma_s ** 2 + (1.0 - p) / p ** 2)


def iir_homogeneous(B: int, G: int, kappa0: float, c: float = 1.0) -> float:
    """Theorem 1 lower bound: c * kappa0 * sqrt(B log G) * G/(G-1)."""
    if G < 2:
        return 1.0
    return c * kappa0 * math.sqrt(B * math.log(G)) * G / (G - 1)


def iir_geometric(B: int, G: int, p: float, sigma_s: float, s_max: float,
                  c: float = 1.0) -> float:
    """Theorem 2 lower bound:
    c * (p/s_max) * sqrt(sigma_s^2 + (1-p)/p^2) * G/(G-1) * sqrt(B log G)."""
    if G < 2:
        return 1.0
    return (c * p / s_max * snapshot_sigma(sigma_s, p)
            * G / (G - 1) * math.sqrt(B * math.log(G)))


def iir_general_drift(B: int, G: int, p: float, sigma_s: float, s_max: float,
                      c: float = 1.0) -> float:
    """Theorem 3 lower bound: c * p*sigma_s/s_max * G/(G-1) * sqrt(B log G)."""
    if G < 2:
        return 1.0
    return (c * p * sigma_s / s_max * G / (G - 1)
            * math.sqrt(B * math.log(G)))


def predicted_fcfs_imbalance(B: int, G: int, sigma_s: float, p: float,
                             c: float = 1.0) -> float:
    """FCFS stationary expected imbalance ~ c*G*sigma_snap*sqrt(B log G)
    (Eq. C18)."""
    return c * G * snapshot_sigma(sigma_s, p) * math.sqrt(B * math.log(max(G, 2)))


def predicted_bfio_imbalance(G: int, s_max: float, p: float) -> float:
    """BF-IO long-run average imbalance <= (G-1) * s_max / p (Lemma 4)."""
    return (G - 1) * s_max / p


def eta_sum_fcfs_lower(B: int, G: int, mu_s: float, sigma_s: float,
                       p: float, c: float = 1.0) -> float:
    """Eq. (17): eta_sum(FCFS) >~ sigma_snap/(mu_s + (1-p)/p) * sqrt(log G / B)."""
    mu_u = mu_s + (1.0 - p) / p
    return c * snapshot_sigma(sigma_s, p) / mu_u * math.sqrt(
        math.log(max(G, 2)) / B)


def energy_saving_guarantee(
    B: int, G: int, p: float, mu_s: float, sigma_s: float, s_max: float,
    pm: PowerModel, c_alpha: float = 1.0, c_eta: float = 1.0,
) -> dict:
    """Remark 1 + Corollary 1: the explicit saving guarantee and its G->inf
    limit for the given power model."""
    alpha = iir_geometric(B, G, p, sigma_s, s_max, c=c_alpha)
    eta = eta_sum_fcfs_lower(B, G, mu_s, sigma_s, p, c=c_eta)
    return {
        "alpha": alpha,
        "eta_sum_lower": eta,
        "saving_bound": saving_bound(alpha, eta, pm),
        "asymptotic_saving": asymptotic_saving(pm),
    }
