"""GPU/TPU power model and the energy theory of Section 5.2.

Power draw is sublinear in utilization (Eq. 7):

    P(u) = P_idle + (P_max - P_idle) * u**gamma,   gamma in (0, 1)

with u = mfu/mfu_sat = L_g / L_max within the synchronized phase (Eqs. 8–9).

Theorem 4 machinery: the exact energy decomposition (C47), the sandwich
bound (C49), the saving bound (16), and Corollary 1's asymptotic limit (18).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "PowerModel",
    "A100_POWER",
    "TPU_V5E_POWER",
    "energy_decomposition",
    "energy_sandwich",
    "saving_bound",
    "asymptotic_saving",
]


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Eq. (7) with the calibration of Appendix D.1."""

    p_idle: float = 100.0     # W
    p_max: float = 400.0      # W
    gamma: float = 0.7
    mfu_sat: float = 0.45
    name: str = "a100"

    def power(self, u) -> np.ndarray:
        """Instantaneous power at utilization fraction u in [0, 1]."""
        u = np.clip(np.asarray(u, dtype=np.float64), 0.0, 1.0)
        return self.p_idle + (self.p_max - self.p_idle) * u ** self.gamma

    @property
    def c_gamma(self) -> float:
        """C_gamma = (1-gamma) P_max + gamma P_idle  (Eq. 15)."""
        return (1.0 - self.gamma) * self.p_max + self.gamma * self.p_idle

    @property
    def d_gamma(self) -> float:
        """D_gamma = (1-gamma)(P_max - P_idle)  (Eq. 15)."""
        return (1.0 - self.gamma) * (self.p_max - self.p_idle)


A100_POWER = PowerModel()  # paper-faithful: 100 W / 400 W / gamma 0.7
# TPU v5e preset (beyond-paper hardware adaptation; envelope numbers):
TPU_V5E_POWER = PowerModel(p_idle=74.0, p_max=197.0, gamma=0.7,
                           mfu_sat=0.45, name="tpu_v5e")


def energy_decomposition(
    loads_per_step: list[np.ndarray] | np.ndarray,
    kappa_att: float,
    pm: PowerModel,
) -> dict:
    """Exact identity (C47):

    E = kappa*P_max*W + kappa*P_idle*ImbTot + kappa*(P_max-P_idle)*X,
    X = sum_{k,g} L*(k) (u^gamma - u),   0 <= X <= (1-gamma) ImbTot.
    """
    e = w = imb = x = 0.0
    for L in loads_per_step:
        L = np.asarray(L, dtype=np.float64)
        lmax = L.max()
        if lmax <= 0:
            continue
        u = L / lmax
        tau = kappa_att * lmax
        e += tau * pm.power(u).sum()
        w += L.sum()
        imb += (lmax - L).sum()
        x += lmax * (u ** pm.gamma - u).sum()
    return {
        "energy": e,
        "W": w,
        "ImbTot": imb,
        "X": x,
        "identity_rhs": kappa_att * (pm.p_max * w + pm.p_idle * imb
                                     + (pm.p_max - pm.p_idle) * x),
    }


def energy_sandwich(W: float, imb_tot: float, kappa_att: float,
                    pm: PowerModel) -> tuple[float, float]:
    """(C49): kappa(P_max W + P_idle ImbTot) <= E <= kappa(P_max W + C_gamma ImbTot)."""
    lo = kappa_att * (pm.p_max * W + pm.p_idle * imb_tot)
    hi = kappa_att * (pm.p_max * W + pm.c_gamma * imb_tot)
    return lo, hi


def saving_bound(alpha: float, eta_sum: float, pm: PowerModel) -> float:
    """Theorem 4, Eq. (16): guaranteed synchronized-phase saving fraction
    given imbalance improvement factor alpha > 1 and baseline normalized
    imbalance eta_sum = ImbTot(pi0)/W."""
    if alpha <= 1.0:
        return 0.0
    num = pm.p_idle * (1.0 - 1.0 / alpha) - pm.d_gamma / alpha
    den = pm.p_max / max(eta_sum, 1e-12) + pm.c_gamma
    return num / den


def asymptotic_saving(pm: PowerModel) -> float:
    """Corollary 1, Eq. (18): limit saving fraction as G -> infinity.

    For A100 (100/400/0.7): 100 / (0.3*400 + 0.7*100) = 100/190 ~= 52.6 %.
    """
    return pm.p_idle / ((1.0 - pm.gamma) * pm.p_max + pm.gamma * pm.p_idle)
