"""Solvers for the step-wise integer optimization (IO) of Section 4.

At step k the scheduler chooses disjoint admit-sets {S_g(k)} minimizing

    J(S(k)) = sum_{h=0..H} Imbalance(k+h)
            = sum_h [ G * max_g Lhat_g(k+h) - sum_g Lhat_g(k+h) ]

subject to |S_g| <= cap[g] and |S| = U(k) = min(|R_wait|, sum_g cap[g]).

Representation
--------------
* ``base``  : (G, H+1) predicted per-worker load trajectories of the jobs
              already resident (h=0 is the current step, after growth and
              completions, before admission).
* ``cands`` : (n, H+1) predicted contribution trajectories of waiting
              candidates, conditional on being admitted at step k.
* An assignment is an int vector a[n] with a[i] in {-1 (not admitted),
  0..G-1}.

The exact (IO) is exponential (the paper's Algorithm 1 enumerates feasible
allocations).  The worst-case theory only needs the minimizer's
*separation / s_max-balance* property (Lemma 1 / Lemma 2), which an
exchange/swap argument produces — so the production solver is greedy
LPT-style construction followed by improving-swap local search: the local
search is literally the proofs' exchange argument run to a fixed point.
``solve_exact`` brute-forces tiny instances for tests.
"""
from __future__ import annotations

import itertools
from typing import Optional

import numpy as np

__all__ = [
    "objective",
    "solve_greedy",
    "local_search",
    "solve_io",
    "solve_exact",
]


def objective(base: np.ndarray, cands: np.ndarray, assign: np.ndarray) -> float:
    """J(S(k;x)) for an assignment vector (Section 4, Eq. (IO) objective)."""
    base = np.asarray(base, dtype=np.float64)
    G, _ = base.shape
    loads = base.copy()
    for i, g in enumerate(assign):
        if g >= 0:
            loads[g] += cands[i]
    return float((G * loads.max(axis=0) - loads.sum(axis=0)).sum())


def _loads_from(base: np.ndarray, cands: np.ndarray,
                assign: np.ndarray) -> np.ndarray:
    loads = np.asarray(base, dtype=np.float64).copy()
    for i, g in enumerate(assign):
        if g >= 0:
            loads[g] += cands[i]
    return loads


def solve_greedy(
    base: np.ndarray,
    caps: np.ndarray,
    cands: np.ndarray,
    n_admit: Optional[int] = None,
) -> np.ndarray:
    """LPT-style greedy: largest candidate first to the worker whose
    windowed max-load increase is smallest (ties -> lower current load).

    Returns the assignment vector a[n] in {-1, 0..G-1}.
    """
    base = np.asarray(base, dtype=np.float64)
    caps = np.asarray(caps, dtype=np.int64).copy()
    cands = np.asarray(cands, dtype=np.float64)
    G, W = base.shape
    n = cands.shape[0]
    U = int(min(n, caps.sum())) if n_admit is None else int(n_admit)
    U = min(U, n, int(caps.sum()))

    assign = np.full(n, -1, dtype=np.int64)
    if U == 0 or n == 0:
        return assign

    loads = base.copy()                       # (G, W)
    order = np.argsort(-cands.sum(axis=1), kind="stable")  # largest total first
    admitted = 0
    for i in order:
        if admitted >= U:
            break
        c = cands[i]                          # (W,)
        # score of placing i on worker g: sum_h max(top1_excluding_g, loads[g]+c)
        top1 = loads.max(axis=0)              # (W,)
        arg1 = loads.argmax(axis=0)           # (W,)
        # second max per h for the exclusion trick
        tmp = loads.copy()
        tmp[arg1, np.arange(W)] = -np.inf
        top2 = tmp.max(axis=0) if G > 1 else np.full(W, -np.inf)
        cand_loads = loads + c[None, :]       # (G, W)
        excl = np.where(np.arange(G)[:, None] == arg1[None, :],
                        top2[None, :], top1[None, :])
        scores = np.maximum(excl, cand_loads).sum(axis=1)  # (G,)
        scores = np.where(caps > 0, scores, np.inf)
        # tie-break on smaller current total load
        g = int(np.lexsort((loads.sum(axis=1), scores))[0])
        if not np.isfinite(scores[g]):
            break
        assign[i] = g
        loads[g] += c
        caps[g] -= 1
        admitted += 1
    return assign


def local_search(
    base: np.ndarray,
    caps: np.ndarray,
    cands: np.ndarray,
    assign: np.ndarray,
    max_iters: int = 256,
) -> np.ndarray:
    """Improving-exchange local search — the exchange argument of
    Lemma 1 / Lemma 2 run to a fixed point (this is what produces the
    s_max-balanced / separation property the theory relies on).

    Per iteration: pick the worker p with the largest windowed load whose
    moves haven't reached a fixed point; consider (all vectorized with a
    top-3 per-column exclusion trick):
      1. relocating each p-candidate to any worker with residual capacity;
      2. swapping each p-candidate with any admitted candidate elsewhere;
      3. swapping each p-candidate with an unadmitted candidate.
    Apply the single best improving move, else try the next-heaviest worker;
    stop when no worker admits an improving move.
    """
    base = np.asarray(base, dtype=np.float64)
    cands = np.asarray(cands, dtype=np.float64)
    caps0 = np.asarray(caps, dtype=np.int64)
    assign = np.asarray(assign, dtype=np.int64).copy()
    G, W = base.shape
    n = cands.shape[0]
    if n == 0 or G < 2:
        return assign

    loads = _loads_from(base, cands, assign)
    used = np.bincount(assign[assign >= 0], minlength=G)
    resid = caps0 - used
    max_wait_considered = 256

    def J(l: np.ndarray) -> float:
        return float((G * l.max(axis=0) - l.sum(axis=0)).sum())

    def top3(l: np.ndarray):
        """Per-column top-3 values and their row indices."""
        k = min(3, G)
        idx = np.argsort(-l, axis=0)[:k]                   # (k, W)
        val = np.take_along_axis(l, idx, axis=0)           # (k, W)
        if k < 3:
            pad_v = np.full((3 - k, W), -np.inf)
            pad_i = np.full((3 - k, W), -1, dtype=np.int64)
            val = np.vstack([val, pad_v])
            idx = np.vstack([idx, pad_i])
        return val, idx

    def excl_two(val, idx, a, b):
        """max over rows excluding rows a and b, per column.

        a, b broadcastable int arrays with trailing shape (..., 1) vs (W,)."""
        e1 = (idx[0][None, :] != a) & (idx[0][None, :] != b)
        e2 = (idx[1][None, :] != a) & (idx[1][None, :] != b)
        return np.where(e1, val[0][None, :],
                        np.where(e2, val[1][None, :], val[2][None, :]))

    cur = J(loads)
    for _ in range(max_iters):
        order = np.argsort(-loads.sum(axis=1))
        # Everything below is invariant across the p-loop (loads/assign only
        # change when a move is applied, which restarts the outer loop), so
        # gather the move-target pools and top-3 exclusion tables once and
        # mask per-p instead of re-compacting per worker.
        val, idx = top3(loads)
        tot = loads.sum(axis=0)
        gs_all = np.nonzero(resid > 0)[0]                   # relocate targets
        lg_all = loads[gs_all]                              # (ng, W)
        Ja = np.nonzero(assign >= 0)[0]                     # admitted pool
        ga = assign[Ja]                                     # (na,)
        ca = cands[Ja]                                      # (na, W)
        la = loads[ga]                                      # (na, W)
        Jw = np.nonzero(assign < 0)[0][:max_wait_considered]
        cw = cands[Jw]                                      # (nw, W)
        applied = False
        for p in order:
            p = int(p)
            Ip = np.nonzero(assign == p)[0]
            if len(Ip) == 0:
                continue
            lp = loads[p]
            cp = cands[Ip]                                  # (np_, W)
            best = (cur - 1e-9, None)

            # 1. relocate i in Ip -> worker g with resid > 0 (g == p masked)
            if len(gs_all) > 0:
                lp_new = lp[None, None, :] - cp[:, None, :]        # (np_,1,W)
                lg_new = lg_all[None, :, :] + cp[:, None, :]       # (np_,ng,W)
                ex = excl_two(val, idx, p, gs_all.reshape(1, -1, 1))
                mx = np.maximum(ex, np.maximum(lp_new, lg_new))
                vals = (G * mx - tot[None, None, :]).sum(axis=2)   # (np_,ng)
                vals[:, gs_all == p] = np.inf
                ai, ag = np.unravel_index(int(np.argmin(vals)), vals.shape)
                if vals[ai, ag] < best[0]:
                    best = (float(vals[ai, ag]),
                            ("rel", int(Ip[ai]), int(gs_all[ag])))

            # 2. swap i in Ip with admitted j on another worker (g_j == p
            #    masked)
            if len(Ja) > 0:
                d = ca[None, :, :] - cp[:, None, :]                # (np_,na,W)
                lp_new = lp[None, None, :] + d
                lg_new = la[None, :, :] - d
                ex = excl_two(val, idx, p, ga.reshape(1, -1, 1))
                mx = np.maximum(ex, np.maximum(lp_new, lg_new))
                vals = (G * mx - tot[None, None, :]).sum(axis=2)
                vals[:, ga == p] = np.inf
                ai, aj = np.unravel_index(int(np.argmin(vals)), vals.shape)
                if vals[ai, aj] < best[0]:
                    best = (float(vals[ai, aj]),
                            ("swap", int(Ip[ai]), int(Ja[aj])))

            # 3. swap i in Ip with unadmitted j (changes the sum term)
            if len(Jw) > 0:
                d = cw[None, :, :] - cp[:, None, :]                # (np_,nw,W)
                lp_new = lp[None, None, :] + d
                ex = excl_two(val, idx, p, p)
                mx = np.maximum(ex, lp_new)
                vals = (G * mx - (tot[None, None, :] + d)).sum(axis=2)
                ai, aj = np.unravel_index(int(np.argmin(vals)), vals.shape)
                if vals[ai, aj] < best[0]:
                    best = (float(vals[ai, aj]),
                            ("adm", int(Ip[ai]), int(Jw[aj])))

            if best[1] is None:
                continue
            kind, i, x = best[1]
            if kind == "rel":
                g = x
                loads[p] -= cands[i]
                loads[g] += cands[i]
                assign[i] = g
                resid[p] += 1
                resid[g] -= 1
            elif kind == "swap":
                j = x
                g = int(assign[j])
                loads[p] += cands[j] - cands[i]
                loads[g] += cands[i] - cands[j]
                assign[i], assign[j] = g, p
            else:  # adm
                j = x
                loads[p] += cands[j] - cands[i]
                assign[j] = p
                assign[i] = -1
            cur = best[0]
            applied = True
            break
        if not applied:
            break
    return assign


def solve_io(
    base: np.ndarray,
    caps: np.ndarray,
    cands: np.ndarray,
    n_admit: Optional[int] = None,
    refine: bool = True,
    max_iters: int = 256,
) -> np.ndarray:
    """Production BF-IO solver: greedy construction + swap refinement."""
    assign = solve_greedy(base, caps, cands, n_admit=n_admit)
    if refine and cands.shape[0] > 1:
        assign = local_search(base, caps, cands, assign, max_iters=max_iters)
    return assign


def solve_exact(
    base: np.ndarray,
    caps: np.ndarray,
    cands: np.ndarray,
    n_admit: Optional[int] = None,
) -> tuple[np.ndarray, float]:
    """Brute-force optimal (IO) solution — tiny instances only (tests)."""
    base = np.asarray(base, dtype=np.float64)
    caps = np.asarray(caps, dtype=np.int64)
    cands = np.asarray(cands, dtype=np.float64)
    G = base.shape[0]
    n = cands.shape[0]
    U = int(min(n, caps.sum())) if n_admit is None else int(n_admit)
    if n > 10 or G > 4:
        raise ValueError("solve_exact is for tiny instances only")

    best: tuple[float, Optional[np.ndarray]] = (np.inf, None)
    for subset in itertools.combinations(range(n), U):
        for placement in itertools.product(range(G), repeat=U):
            used = np.bincount(placement, minlength=G)
            if np.any(used > caps):
                continue
            a = np.full(n, -1, dtype=np.int64)
            for idx, g in zip(subset, placement):
                a[idx] = g
            v = objective(base, cands, a)
            if v < best[0] - 1e-12:
                best = (v, a)
    assert best[1] is not None, "no feasible assignment"
    return best[1], best[0]
