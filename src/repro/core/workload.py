"""Workload model from Section 3 of the paper.

A request i is a workload profile W_i = (w_i^(1), ..., w_i^(o_i)):
``o_i`` processing steps, each contributing workload w_i^(j) >= 0.

The paper's LLM decode specialization (Section 5): w_i^(1) = s_i (prefill
size), and the j-th decode step costs s_i + sum_{t<j} delta_t where
(delta_k) is the common non-decreasing drift sequence (Definition 2):

  * delta_k == 1 : standard KV-cache growth (dense / MoE / VLM / audio)
  * delta_k == 0 : constant per-step workload (SSM state, classical jobs)
  * 0 < delta_k < 1 : compressed / hybrid caches (e.g. Zamba2 shared attn)

Workloads are *unknown to the scheduler* at arrival; the scheduler only
observes current loads and (optionally) a short-lookahead prediction.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional, Sequence

import numpy as np

__all__ = [
    "DriftModel",
    "Request",
    "ArrivalInstance",
    "constant_drift",
    "unit_drift",
    "fractional_drift",
    "drift_for_family",
]


@dataclasses.dataclass(frozen=True)
class DriftModel:
    """Common per-step workload increment sequence (Definition 2).

    ``delta(k)`` must be in [0, delta_max] for all global steps k >= 1.
    """

    name: str
    delta_max: float
    delta: Callable[[int], float]

    def increment(self, k: int) -> float:
        d = float(self.delta(int(k)))
        if not (0.0 <= d <= self.delta_max + 1e-12):
            raise ValueError(
                f"drift {self.name}: delta({k})={d} outside [0, {self.delta_max}]"
            )
        return d

    def cumulative(self, k_start: int, n: int) -> float:
        """Sum of delta over global steps k_start+1 .. k_start+n."""
        return float(sum(self.increment(k_start + 1 + t) for t in range(int(n))))


def unit_drift() -> DriftModel:
    """delta_k == 1: one token of KV per decode step (paper's main model)."""
    return DriftModel(name="unit", delta_max=1.0, delta=lambda k: 1.0)


def constant_drift() -> DriftModel:
    """delta_k == 0: constant workload (SSM decode, classical scheduling)."""
    return DriftModel(name="constant", delta_max=0.0, delta=lambda k: 0.0)


def fractional_drift(frac: float) -> DriftModel:
    """delta_k == frac in (0,1): only a fraction of layers grow KV (hybrid)."""
    if not (0.0 < frac < 1.0):
        raise ValueError(f"fractional drift must be in (0,1), got {frac}")
    return DriftModel(name=f"fractional[{frac:g}]", delta_max=frac,
                      delta=lambda k: frac)


def scaled_drift(c: float) -> DriftModel:
    """delta_k == c >= 0: speculative decoding accepts ~c tokens per step
    (the paper's delta_k >= 1 case of Definition 2)."""
    if c < 0:
        raise ValueError(f"drift must be >= 0, got {c}")
    return DriftModel(name=f"scaled[{c:g}]", delta_max=c, delta=lambda k: c)


def drift_for_family(family: str) -> DriftModel:
    """Map an architecture family to its workload drift model (DESIGN.md §5)."""
    family = family.lower()
    if family in ("dense", "moe", "vlm", "audio"):
        return unit_drift()
    if family == "ssm":
        return constant_drift()
    if family == "hybrid":
        # Zamba2: ~6 shared-attention applications over 38 blocks grow KV;
        # SSM blocks carry constant state.  Effective drift ~ 6/38.
        return fractional_drift(6.0 / 38.0)
    raise ValueError(f"unknown architecture family: {family!r}")


@dataclasses.dataclass
class Request:
    """One inference request with its (hidden) workload profile."""

    rid: int
    arrival_step: int          # k_i: step at which it enters the waiting pool
    prefill: float             # s_i = w_i^(1)
    decode_len: int            # o_i: total number of processing steps
    arrival_time: float = float("nan")  # wall-clock arrival (trace mode)
    # Mutable scheduling state:
    assign_step: int = -1      # x_i (-1 = unassigned)
    worker: int = -1           # g(i)
    steps_done: int = 0        # number of processing steps completed
    finish_step: int = -1
    # Wall-clock bookkeeping (filled by the simulator):
    t_start: float = float("nan")
    t_finish: float = float("nan")

    @property
    def active(self) -> bool:
        return self.worker >= 0 and self.finish_step < 0

    @property
    def done(self) -> bool:
        return self.finish_step >= 0

    def workload_at(self, k: int, drift: DriftModel) -> float:
        """w_i^(j) for the step j = k - x_i + 1 (k is a global step index).

        Requires the request to be active at step k.
        """
        if self.assign_step < 0 or k < self.assign_step:
            raise ValueError(f"request {self.rid} not active at step {k}")
        j = k - self.assign_step  # 0-based processing-step index
        if j >= self.decode_len:
            raise ValueError(f"request {self.rid} already finished by step {k}")
        return self.prefill + drift.cumulative(self.assign_step, j)

    def profile(self, drift: DriftModel) -> np.ndarray:
        """Full workload profile W_i (assuming assignment at step 0)."""
        out = np.empty(self.decode_len, dtype=np.float64)
        acc = self.prefill
        out[0] = acc
        for j in range(1, self.decode_len):
            acc += drift.increment(j)
            out[j] = acc
        return out

    def total_work(self, drift: DriftModel) -> float:
        """sum_j w_i^(j) — the request's policy-independent contribution."""
        return float(self.profile(drift).sum())


@dataclasses.dataclass
class ArrivalInstance:
    """An arrival instance I: requests with arrival steps (Section 3).

    ``requests`` must be sorted by arrival_step (FCFS pops in this order).
    """

    requests: list[Request]
    drift: DriftModel = dataclasses.field(default_factory=unit_drift)
    name: str = "instance"

    def __post_init__(self) -> None:
        steps = [r.arrival_step for r in self.requests]
        if steps != sorted(steps):
            self.requests = sorted(self.requests, key=lambda r: r.arrival_step)

    def __len__(self) -> int:
        return len(self.requests)

    def arrivals_at(self, k: int) -> Iterator[Request]:
        for r in self.requests:
            if r.arrival_step == k:
                yield r

    def total_work(self) -> float:
        """W(I) of Eq. (11): policy independent."""
        return float(sum(r.total_work(self.drift) for r in self.requests))

    def reset(self) -> None:
        for r in self.requests:
            r.assign_step = -1
            r.worker = -1
            r.steps_done = 0
            r.finish_step = -1
            r.t_start = float("nan")
            r.t_finish = float("nan")


def make_instance(
    *,
    n_requests: int,
    prefill_sampler: Callable[[np.random.Generator, int], np.ndarray],
    decode_sampler: Callable[[np.random.Generator, int], np.ndarray],
    arrival_steps: Optional[Sequence[int]] = None,
    drift: Optional[DriftModel] = None,
    seed: int = 0,
    name: str = "synthetic",
) -> ArrivalInstance:
    """Build an ArrivalInstance from samplers (used by repro.data.traces)."""
    rng = np.random.default_rng(seed)
    s = np.asarray(prefill_sampler(rng, n_requests), dtype=np.float64)
    o = np.asarray(decode_sampler(rng, n_requests), dtype=np.int64)
    if np.any(s < 0) or np.any(o < 1):
        raise ValueError("prefill must be >=0 and decode_len >= 1")
    if arrival_steps is None:
        arrival_steps = [0] * n_requests
    reqs = [
        Request(rid=i, arrival_step=int(arrival_steps[i]),
                prefill=float(s[i]), decode_len=int(o[i]))
        for i in range(n_requests)
    ]
    return ArrivalInstance(requests=reqs, drift=drift or unit_drift(), name=name)
