"""Device-side routed serving loop: the whole admit→decode→complete cycle
(including the BF-IO assignment) under one jitted lax.scan — zero host
round-trips between steps.

    PYTHONPATH=src python examples/device_loop_demo.py
"""
import numpy as np

from repro.serving import init_loop_state, make_device_serving_loop

G, B, WAIT_CAP = 8, 8, 256
rng = np.random.default_rng(0)

# bimodal workload: a few heavy prompts among many light ones
sizes = np.concatenate([rng.uniform(200, 300, 24), rng.uniform(5, 30, 104)])
remaining = rng.integers(4, 24, len(sizes))

run = make_device_serving_loop(G, B, WAIT_CAP)
state = init_loop_state(G, B, sizes, remaining, WAIT_CAP)

print(f"{len(sizes)} requests onto {G} workers x {B} slots, jitted loop:")
for chunk in range(4):
    state = run(state, 16)
    active = int(state.slot_active.sum())
    waiting = int((state.wait_prefill > 0).sum())
    print(f"  after {int(state.tot_steps):3d} steps: active={active:3d} "
          f"waiting={waiting:3d} "
          f"cum-imbalance={float(state.tot_imbalance):9.1f}")
assert int(state.slot_active.sum()) == 0
print("all requests served on device — avg per-step imbalance "
      f"{float(state.tot_imbalance)/int(state.tot_steps):.1f}")
