"""Train a ~small LM for a few hundred steps on synthetic data — exercises
the full training substrate (model, AdamW, schedule, checkpointing).

    PYTHONPATH=src python examples/train_small.py
"""
import tempfile

import jax

from repro.configs.base import ModelConfig
from repro.data import token_batches
from repro.launch.mesh import make_cpu_mesh
from repro.models import init_params, split_params
from repro.training import AdamWConfig, load_checkpoint, train

cfg = ModelConfig(
    name="demo-120m", family="dense",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
    vocab_size=2048, dtype="float32",
)
print(f"model: {cfg.n_params()/1e6:.1f}M params")

params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
mesh = make_cpu_mesh()

STEPS = 200
batches = token_batches(vocab_size=cfg.vocab_size, batch=8, seq_len=64,
                        n_batches=STEPS, seed=0)
with tempfile.TemporaryDirectory() as ckpt_dir:
    params, losses = train(
        cfg, params=params, batches=batches,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=STEPS),
        mesh=mesh, log_every=25, ckpt_dir=ckpt_dir, ckpt_every=100)
    restored, step = load_checkpoint(ckpt_dir, {"params": params,
                                                "opt_m": params,
                                                "opt_v": params})
    print(f"checkpoint restored from step {step}")
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({'OK' if losses[-1] < losses[0] else 'NO PROGRESS'})")
