"""End-to-end serving driver: a real (reduced) model served with batched
requests through the BF-IO-routed multi-worker engine.

Loads the granite-8b smoke variant, submits a heterogeneous batch of
requests, and runs FCFS vs BF-IO through the full engine (prefill ->
sticky placement -> barrier-stepped decode -> completion), verifying that
generated tokens are identical while efficiency differs.  The paged
backend is then driven through its full memory hierarchy:

* ``EngineConfig.prefix_cache=True`` — identical prompt prefixes share
  KV blocks (content-hash index, copy-on-write on divergence), so
  resident KV scales with *unique* content;
* ``EngineConfig.paged_pool_blocks`` undersized + ``preemption_mode=
  "swap"`` — the pool holds only half the peak demand and the engine
  preempts victims (host-side swap, LIFO) instead of raising
  ``MemoryError``, with bit-identical outputs (``"recompute"`` drops
  victims' KV and re-prefills instead — less host traffic, more FLOPs).

Finally the *fleet* tier (``repro.fleet``): the same model served by R
engine replicas behind a fleet router on a timed flash-crowd scenario —
round-robin vs two-tier BF-IO (router balances replicas, each replica's
scheduler balances its workers), with identical generations and the
efficiency gap read from the telemetry subsystem.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import make_policy
from repro.launch.mesh import make_cpu_mesh
from repro.models import init_params, split_params
from repro.serving import EngineConfig, ServeRequest, ServingEngine

cfg = get_smoke_config("granite-8b")
params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
mesh = make_cpu_mesh()


def make_requests():
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(24):
        # bimodal prompt lengths: the regime where routing matters
        n = int(rng.integers(40, 60)) if i % 3 == 0 else int(
            rng.integers(4, 12))
        reqs.append(ServeRequest(
            rid=i, tokens=rng.integers(1, cfg.vocab_size, size=n),
            max_new_tokens=int(rng.integers(4, 12))))
    return reqs


results = {}
for policy in ["fcfs", "bfio_h0"]:
    engine = ServingEngine(
        cfg, params,
        EngineConfig(n_workers=2, slots_per_worker=4, max_seq_len=128),
        make_policy(policy), mesh=mesh)
    reqs = make_requests()
    for r in reqs:
        engine.submit(r)
    stats = engine.run()
    results[policy] = (stats, reqs)
    print(f"{policy:>8s}: {stats['tokens']} tokens, "
          f"{stats['steps']} steps, {stats['time_s']:.3f}s simulated, "
          f"imbalance {stats['avg_imbalance']:.1f}, "
          f"energy {stats['energy_j']:.1f} J")

# placement invariance: outputs must not depend on the router
gen_f = [r.generated for r in results["fcfs"][1]]
gen_b = [r.generated for r in results["bfio_h0"][1]]
assert gen_f == gen_b, "outputs must be identical across routers!"
print("\nOK: identical generations; BF-IO changed only efficiency "
      f"(imbalance /"
      f"{results['fcfs'][0]['avg_imbalance'] / max(results['bfio_h0'][0]['avg_imbalance'], 1e-9):.1f})")

# cache-backend invariance: the same requests through the paged KV cache
# (vLLM block tables + chunked prefill) must match the slot layout
# bit-for-bit — memory layout, like routing, is a pure efficiency knob
engine = ServingEngine(
    cfg, params,
    EngineConfig(n_workers=2, slots_per_worker=4, max_seq_len=128,
                 cache_backend="paged", paged_block_size=16,
                 prefill_chunk=32),
    make_policy("bfio_h0"), mesh=mesh)
reqs = make_requests()
for r in reqs:
    engine.submit(r)
paged_stats = engine.run()
assert [r.generated for r in reqs] == gen_b, \
    "paged backend diverged from the slot cache!"
assert paged_stats["tokens"] == results["bfio_h0"][0]["tokens"]
dense = engine.backend.pool_bytes()
print(f"OK: paged+chunked backend identical generations "
      f"({paged_stats['tokens']} tokens in {paged_stats['steps']} steps "
      f"— chunking spreads the admission waves); peak resident KV "
      f"{engine.kv_peak_bytes / 1e6:.2f} MB "
      f"({engine.kv_peak_bytes / dense:.0%} of the {dense / 1e6:.2f} MB "
      f"the slot layout pins)")
peak_blocks = -(-engine.kv_peak_bytes * engine.backend.n_blocks
                // max(engine.backend.pool_bytes(), 1))

# memory pressure: a pool sized at half the peak demand — the engine
# preempts (swap mode: victims' blocks staged host-side, restored
# bit-for-bit on resume) and still produces identical generations
engine = ServingEngine(
    cfg, params,
    EngineConfig(n_workers=2, slots_per_worker=4, max_seq_len=128,
                 cache_backend="paged", paged_block_size=16,
                 paged_pool_blocks=max(int(peak_blocks) // 2, 4),
                 preemption_mode="swap"),
    make_policy("bfio_h0"), mesh=mesh)
reqs = make_requests()
for r in reqs:
    engine.submit(r)
stats = engine.run(max_steps=5000)
assert [r.generated for r in reqs] == gen_b, \
    "swap preemption changed the outputs!"
assert stats["preemptions"] > 0
print(f"OK: pool at ~0.5x peak demand served everything via "
      f"{stats['preemptions']} preemptions ({stats['tokens_swapped']} KV "
      f"tokens swapped) with bit-identical generations")

# prefix caching: a shared system prompt is stored once and every
# request add-refs the shared blocks (copy-on-write on divergence)
rng = np.random.default_rng(11)
system = rng.integers(1, cfg.vocab_size, size=48)
engine = ServingEngine(
    cfg, params,
    EngineConfig(n_workers=2, slots_per_worker=4, max_seq_len=128,
                 cache_backend="paged", paged_block_size=16,
                 prefix_cache=True),
    make_policy("bfio_h0"), mesh=mesh)
reqs = [ServeRequest(rid=i,
                     tokens=np.concatenate(
                         [system,
                          rng.integers(1, cfg.vocab_size,
                                       size=int(rng.integers(4, 12)))]),
                     max_new_tokens=8) for i in range(16)]
for r in reqs:
    engine.submit(r)
stats = engine.run()
assert stats["prefix_hit_rate"] > 0
print(f"OK: prefix cache on a shared system prompt — "
      f"{stats['prefix_hits']}/{stats['prefix_queries']} block hits "
      f"({stats['prefix_hit_rate']:.0%}), peak resident KV "
      f"{engine.kv_peak_bytes / 1e6:.2f} MB")

# ----------------------------------------------------------------------
# Fleet mode: R=2 replicas behind a fleet router on a timed flash-crowd
# scenario.  Routing — like placement and memory layout above — is a
# pure efficiency knob: dense greedy decode is placement-invariant, so
# the generations must match across routers while imbalance and
# energy-per-token differ.  Metrics come from the telemetry subsystem
# (per-step per-replica records, JSONL-exportable).
# ----------------------------------------------------------------------
from repro.fleet import FleetServer, FleetTelemetry, make_scenario

scenario = make_scenario("flash_crowd", n_requests=24, n_replicas=2,
                         n_workers=2, slots_per_worker=4,
                         max_seq_len=128, vocab_size=cfg.vocab_size,
                         seed=3, step_overhead=1e-3, t_token=2e-4)
fleet_ec = EngineConfig(n_workers=2, slots_per_worker=4, max_seq_len=128,
                        step_overhead=1e-3, t_token=2e-4)
fleet_runs = {}
for router in ["round_robin", "bfio"]:
    tel = FleetTelemetry()
    fleet = FleetServer(cfg, params, fleet_ec, n_replicas=2,
                        router=router, policy="bfio_h0", mesh=mesh,
                        telemetry=tel)
    fleet.submit_scenario(scenario)
    stats = fleet.run()
    summary = tel.summary()
    fleet_runs[router] = (stats, summary,
                          [r.generated for r in fleet.requests])
    print(f"{router:>12s}: {stats['tokens']} tokens, "
          f"imbalance {stats['avg_cross_imbalance']:.1f}, "
          f"{stats['energy_per_token']:.3f} J/tok "
          f"({stats['idle_j']:.1f} J barrier idle), "
          f"TTFT p95 {summary['ttft']['p95']:.3f}s")

assert fleet_runs["round_robin"][2] == fleet_runs["bfio"][2], \
    "fleet outputs must not depend on the router!"
assert all(s["failed"] == 0 for s, _, _ in fleet_runs.values())
print("OK: fleet tier — identical generations across routers; two-tier "
      "BF-IO moved only the efficiency "
      f"(imbalance {fleet_runs['round_robin'][0]['avg_cross_imbalance']:.1f}"
      f" -> {fleet_runs['bfio'][0]['avg_cross_imbalance']:.1f})")

# ----------------------------------------------------------------------
# Scaling the replica axis (the ``fleet_scale`` regime).  The fleet hot
# path is vectorized (``fleet_mode="vec"``, the default): per-replica
# loads/counts/free-slots live in incrementally-updated numpy arrays
# instead of per-step Python gathers, so per-step fleet overhead stays
# O(touched replicas) instead of O(R).  The pre-vectorization loop stays
# live as ``fleet_mode="ref"`` and both modes must agree bit-for-bit —
# vectorization, like routing, must be a pure efficiency knob.
# ----------------------------------------------------------------------
ref_vec = {}
for fleet_mode in ["ref", "vec"]:
    fleet = FleetServer(cfg, params, fleet_ec, n_replicas=4,
                        router="bfio", policy="bfio_h0", mesh=mesh,
                        fleet_mode=fleet_mode)
    fleet.submit_scenario(scenario)
    ref_vec[fleet_mode] = (fleet.run(),
                           [r.generated for r in fleet.requests])
assert ref_vec["ref"] == ref_vec["vec"], \
    "vectorized fleet path diverged from the reference loop!"
print("OK: fleet_mode='vec' bit-identical to the ref loop at R=4 "
      f"({ref_vec['vec'][0]['steps']} steps, "
      f"{ref_vec['vec'][0]['tokens']} tokens)")

# At R in the hundreds a single global BF-IO solve per step is itself a
# bottleneck, so the router goes hierarchical: replicas are grouped into
# pods, one *batched* BF-IO solve scores all pods at once, then a
# per-pod solve places within the winner.  A predicted-output-length
# term ("oracle" reads each request's decode budget) sharpens the
# router's load estimates; heterogeneous replica classes (mixed
# worker/slot shapes) exercise capacity-aware routing.
pod_scenario = make_scenario("steady", n_requests=48, n_replicas=12,
                             n_workers=1, slots_per_worker=2,
                             max_seq_len=128, vocab_size=cfg.vocab_size,
                             seed=5, step_overhead=1e-3, t_token=2e-4)
pod_ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=128,
                      step_overhead=1e-3, t_token=2e-4)
fleet = FleetServer(cfg, params, pod_ec, n_replicas=12,
                    router="pod_bfio_p4", policy="bfio_h0", mesh=mesh,
                    predictor="oracle")
fleet.submit_scenario(pod_scenario)
pod_stats = fleet.run()
assert pod_stats["failed"] == 0
print(f"OK: hierarchical pod routing (R=12, 4 pods, oracle length "
      f"predictor) — {pod_stats['tokens']} tokens, imbalance "
      f"{pod_stats['avg_cross_imbalance']:.1f}, "
      f"{pod_stats['energy_per_token']:.3f} J/tok")

# heterogeneous fleet: two small replicas (2 slots) + two large (4
# slots), grouped by class into pods behind the capacity-normalized
# pod router — under sustained pressure the large class absorbs more
# work in proportion to its capacity
import dataclasses

classes = [(2, pod_ec),
           (2, dataclasses.replace(pod_ec, slots_per_worker=4))]
fleet = FleetServer(cfg, params, pod_ec, router="pod_bfio_p2",
                    policy="bfio_h0", mesh=mesh, replica_classes=classes)
fleet.submit_scenario(make_scenario(
    "flash_crowd", n_requests=96, n_replicas=4, n_workers=1,
    slots_per_worker=3, max_seq_len=128, vocab_size=cfg.vocab_size,
    seed=9, step_overhead=1e-3, t_token=2e-4))
het_stats = fleet.run()
assert het_stats["failed"] == 0
small = sum(r["tokens"] for r in het_stats["replicas"][:2])
large = sum(r["tokens"] for r in het_stats["replicas"][2:])
assert large > small, "capacity-aware routing should favor the large class"
print(f"OK: heterogeneous fleet (2x 2-slot + 2x 4-slot pods) — "
      f"capacity-normalized routing sent {large} tokens to the large "
      f"class vs {small} to the small ({het_stats['tokens']} total, "
      f"0 failed)")

# ----------------------------------------------------------------------
# The async event-driven fleet (``repro.fleet.async_server``): replicas
# advance on their own clocks (no barrier), the router places arrivals
# against staleness-bounded load snapshots, and an autoscaler turns the
# replica count into a control variable on a diurnal trace — idle
# replicas power off through the trough and warm back up for the peak.
# Draining replicas hand resident requests off through the paged
# backend's host-staged swap path, so scaling — like every knob above —
# must be a pure efficiency decision: generations bit-identical to a
# fleet that never scaled, zero tokens recomputed.
# ----------------------------------------------------------------------
from repro.fleet import AsyncFleetServer, TargetUtilizationAutoscaler

async_ec = EngineConfig(n_workers=2, slots_per_worker=4, max_seq_len=128,
                        cache_backend="paged", paged_block_size=16,
                        preemption_mode="swap",
                        step_overhead=1e-3, t_token=2e-4)
diurnal = make_scenario("diurnal", n_requests=64, n_replicas=4,
                        n_workers=2, slots_per_worker=4, max_seq_len=128,
                        vocab_size=cfg.vocab_size, seed=5,
                        load_factor=0.4, step_overhead=1e-3,
                        t_token=2e-4)

fixed = AsyncFleetServer(cfg, params, async_ec, n_replicas=4,
                         router="bfio", policy="bfio_h0", mesh=mesh)
fixed.submit_scenario(diurnal)
fixed_stats = fixed.run()

scaled = AsyncFleetServer(
    cfg, params, async_ec, n_replicas=4, router="bfio",
    policy="bfio_h0", mesh=mesh, max_snapshot_age=0.05,
    autoscaler=TargetUtilizationAutoscaler(
        r_min=1, r_max=4, target=0.7, interval_s=0.05, warmup_s=0.02))
scaled.submit_scenario(diurnal)
scaled_stats = scaled.run()

assert [r.generated for r in scaled.requests] == \
    [r.generated for r in fixed.requests], \
    "autoscaling changed the outputs!"
assert scaled_stats["failed"] == 0
assert scaled_stats["drain_handoffs"] > 0
assert scaled_stats["drain_tokens_lost"] == 0
assert scaled_stats["idle_j"] < fixed_stats["idle_j"]
print(f"OK: async autoscaled fleet on the diurnal trough — idle energy "
      f"{fixed_stats['idle_j']:.1f} -> {scaled_stats['idle_j']:.1f} J, "
      f"{scaled_stats['energy_per_token']:.3f} vs "
      f"{fixed_stats['energy_per_token']:.3f} J/tok, mean replicas on "
      f"{scaled_stats['r_on_mean']:.2f}/4, "
      f"{scaled_stats['drain_handoffs']} drain handoff(s) with 0 tokens "
      f"recomputed and bit-identical generations")

# ----------------------------------------------------------------------
# Observability (``repro.obs``): the same diurnal run under the span
# recorder — every request's lifecycle lands in a Chrome-trace/Perfetto
# JSON on the deterministic sim clock, and each barrier step's idle
# joules are decomposed by cause into the straggler ledger.  Both are
# exact, not approximate: the ledger total folds to the fleet's
# ``idle_j`` bit-for-bit, and every trace request-span's ``e2e_s``
# equals the telemetry latency bit-for-bit.
# ----------------------------------------------------------------------
import os
import tempfile

from repro.fleet import SLOSpec
from repro.obs import SpanRecorder, fold_sum, read_trace, write_trace

rec = SpanRecorder()
tel = FleetTelemetry(slo=SLOSpec(ttft_s=0.5, tpot_s=0.1))
traced = FleetServer(cfg, params, async_ec, n_replicas=4,
                     router="bfio", policy="bfio_h0", mesh=mesh,
                     telemetry=tel, obs=rec)
traced.submit_scenario(diurnal)
traced_stats = traced.run()

ledger = traced.straggler_ledger()
assert ledger["total_idle_j"] == traced_stats["idle_j"]
assert all(fold_sum(s["idle_split"]) == s["idle_j"] for s in tel.steps)

trace_path = os.path.join(tempfile.mkdtemp(prefix="serve_cluster_"),
                          "diurnal.trace")
write_trace(rec, trace_path)
seen = read_trace(trace_path)
lat = {q["rid"]: q["latency"] for q in tel.requests}
assert set(seen["requests"]) == set(lat)
assert all(v["e2e_s"] == lat[rid] for rid, v in seen["requests"].items())

print(f"\nOK: traced diurnal run — {rec.n_events} span events across "
      f"{len(seen['requests'])} requests round-tripped through "
      f"{trace_path} (every e2e_s bit-equal to the telemetry latency); "
      f"straggler ledger folds to idle_j = {traced_stats['idle_j']:.3f} J "
      f"bit-exactly:")
print(traced.format_straggler_ledger())
