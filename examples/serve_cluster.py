"""End-to-end serving driver: a real (reduced) model served with batched
requests through the BF-IO-routed multi-worker engine.

Loads the granite-8b smoke variant, submits a heterogeneous batch of
requests, and runs FCFS vs BF-IO through the full engine (prefill ->
sticky placement -> barrier-stepped decode -> completion), verifying that
generated tokens are identical while efficiency differs.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import make_policy
from repro.launch.mesh import make_cpu_mesh
from repro.models import init_params, split_params
from repro.serving import EngineConfig, ServeRequest, ServingEngine

cfg = get_smoke_config("granite-8b")
params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
mesh = make_cpu_mesh()


def make_requests():
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(24):
        # bimodal prompt lengths: the regime where routing matters
        n = int(rng.integers(40, 60)) if i % 3 == 0 else int(
            rng.integers(4, 12))
        reqs.append(ServeRequest(
            rid=i, tokens=rng.integers(1, cfg.vocab_size, size=n),
            max_new_tokens=int(rng.integers(4, 12))))
    return reqs


results = {}
for policy in ["fcfs", "bfio_h0"]:
    engine = ServingEngine(
        cfg, params,
        EngineConfig(n_workers=2, slots_per_worker=4, max_seq_len=128),
        make_policy(policy), mesh=mesh)
    reqs = make_requests()
    for r in reqs:
        engine.submit(r)
    stats = engine.run()
    results[policy] = (stats, reqs)
    print(f"{policy:>8s}: {stats['tokens']} tokens, "
          f"{stats['steps']} steps, {stats['time_s']:.3f}s simulated, "
          f"imbalance {stats['avg_imbalance']:.1f}, "
          f"energy {stats['energy_j']:.1f} J")

# placement invariance: outputs must not depend on the router
gen_f = [r.generated for r in results["fcfs"][1]]
gen_b = [r.generated for r in results["bfio_h0"][1]]
assert gen_f == gen_b, "outputs must be identical across routers!"
print("\nOK: identical generations; BF-IO changed only efficiency "
      f"(imbalance /"
      f"{results['fcfs'][0]['avg_imbalance'] / max(results['bfio_h0'][0]['avg_imbalance'], 1e-9):.1f})")

# cache-backend invariance: the same requests through the paged KV cache
# (vLLM block tables + chunked prefill) must match the slot layout
# bit-for-bit — memory layout, like routing, is a pure efficiency knob
engine = ServingEngine(
    cfg, params,
    EngineConfig(n_workers=2, slots_per_worker=4, max_seq_len=128,
                 cache_backend="paged", paged_block_size=16,
                 prefill_chunk=32),
    make_policy("bfio_h0"), mesh=mesh)
reqs = make_requests()
for r in reqs:
    engine.submit(r)
paged_stats = engine.run()
assert [r.generated for r in reqs] == gen_b, \
    "paged backend diverged from the slot cache!"
assert paged_stats["tokens"] == results["bfio_h0"][0]["tokens"]
dense = engine.backend.pool_bytes()
print(f"OK: paged+chunked backend identical generations "
      f"({paged_stats['tokens']} tokens in {paged_stats['steps']} steps "
      f"— chunking spreads the admission waves); peak resident KV "
      f"{engine.kv_peak_bytes / 1e6:.2f} MB "
      f"({engine.kv_peak_bytes / dense:.0%} of the {dense / 1e6:.2f} MB "
      f"the slot layout pins)")
