"""Energy-theory walk-through (Theorem 4 / Corollary 1).

Shows the exact energy decomposition (C47), the sandwich bound (C49), the
guaranteed-saving bound (16) against a measured FCFS vs BF-IO pair, and
the hardware-dependent Corollary-1 limits for A100 vs a TPU-v5e preset.

    PYTHONPATH=src python examples/energy_ablation.py
"""
import numpy as np

from repro.core import (
    A100_POWER,
    TPU_V5E_POWER,
    SimConfig,
    SimTrace,
    asymptotic_saving,
    energy_decomposition,
    energy_sandwich,
    make_policy,
    saving_bound,
    simulate,
)
from repro.data import LONGBENCH_LIKE, batched_rounds_instance

G, B = 16, 24
inst = batched_rounds_instance(LONGBENCH_LIKE, G=G, B=B, n_rounds=4, seed=1)
cfg = SimConfig(G=G, B=B)

runs = {}
for name in ["fcfs", "bfio_h20"]:
    tr = SimTrace()
    cfg_t = SimConfig(G=G, B=B, record_loads_every=1)
    m = simulate(inst, make_policy(name), cfg_t, trace=tr)
    runs[name] = (m, tr)
    print(f"{name:>9s}: E = {m.energy_joules/1e6:.3f} MJ, "
          f"ImbTot = {m.total_imbalance:.3e}, eta_sum = {m.eta_sum:.3f}")

# --- exact decomposition on the recorded load trajectories -------------
m_f, tr_f = runs["fcfs"]
d = energy_decomposition(tr_f.loads, kappa_att=cfg.t_token, pm=A100_POWER)
print(f"\ndecomposition identity (C47): E = {d['energy']:.4g}, "
      f"rhs = {d['identity_rhs']:.4g} "
      f"(match: {abs(d['energy']-d['identity_rhs'])/d['energy'] < 1e-9})")
lo, hi = energy_sandwich(d["W"], d["ImbTot"], cfg.t_token, A100_POWER)
print(f"sandwich (C49): {lo:.4g} <= {d['energy']:.4g} <= {hi:.4g}")

# --- Theorem 4 bound vs measurement -------------------------------------
m_b, _ = runs["bfio_h20"]
alpha = m_f.avg_imbalance / m_b.avg_imbalance
bound = saving_bound(alpha, m_f.eta_sum, A100_POWER)
measured = 1 - m_b.energy_joules / m_f.energy_joules
print(f"\nThm 4: alpha = {alpha:.2f} -> guaranteed saving >= {bound:.2%}; "
      f"measured = {measured:.2%}")

# --- Corollary 1 hardware limits ----------------------------------------
print(f"\nCor 1 asymptotic savings (G -> inf):")
for pm in (A100_POWER, TPU_V5E_POWER):
    print(f"  {pm.name:8s} (idle {pm.p_idle:.0f} W / peak {pm.p_max:.0f} W):"
          f" {asymptotic_saving(pm):.1%}")
print("(the paper's 52.6 % figure is the A100 instantiation — Remark 2)")
