"""Quickstart: the BF-IO principle in 60 seconds.

Simulates the paper's decode-stage serving system (Section 6) at reduced
scale, comparing the default FCFS router against BF-IO, and prints the
four paper metrics.  Runs on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import SimConfig, make_policy, simulate
from repro.data import LONGBENCH_LIKE, overload_rate, poisson_trace

G, B = 16, 24                       # 16 workers, 24 slots each

rate = overload_rate(LONGBENCH_LIKE, G, B, factor=1.5)
instance = poisson_trace(LONGBENCH_LIKE, n_requests=G * B * 4, rate=rate,
                         seed=0)
config = SimConfig(G=G, B=B, time_based_arrivals=True)

print(f"{'policy':>10s} {'imbalance':>12s} {'tok/s':>10s} "
      f"{'TPOT(s)':>9s} {'energy(MJ)':>11s} {'idle':>6s}")
baseline = None
for name in ["fcfs", "jsq", "bfio_h0", "bfio_h20"]:
    policy = make_policy(name)
    m = simulate(instance, policy, config)
    print(f"{m.policy:>10s} {m.avg_imbalance:12.3e} {m.throughput:10.1f} "
          f"{m.tpot:9.4f} {m.energy_joules/1e6:11.3f} "
          f"{m.mean_idle_frac:6.1%}")
    if baseline is None:
        baseline = m
print(f"\nBF-IO(H=20) vs FCFS: imbalance /"
      f"{baseline.avg_imbalance / m.avg_imbalance:.1f}, "
      f"throughput +{m.throughput / baseline.throughput - 1:.0%}, "
      f"energy -{1 - m.energy_joules / baseline.energy_joules:.0%}")
